"""Kernel-vs-oracle correctness: the CORE L1 signal.

Hypothesis sweeps shapes/values for all three Pallas kernels against the
pure-jnp references in ``compile.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, logprob, ref, spec_accept

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-5


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(
    b=st.sampled_from([1, 2, 3]),
    h=st.sampled_from([1, 2, 4]),
    t=st.sampled_from([8, 16, 32, 64]),
    dh=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(b, h, t, dh, seed):
    r = _rng(seed)
    q, k, v = (jnp.asarray(r.standard_normal((b, h, t, dh), np.float32)) for _ in range(3))
    # left-padded valid patterns: random prefix of pads per row
    pads = r.integers(0, t - 1, b)
    valid = np.ones((b, t), np.float32)
    for i, p in enumerate(pads):
        valid[i, :p] = 0.0
    valid = jnp.asarray(valid)
    scale = 1.0 / np.sqrt(dh)
    got = attention.attention(q, k, v, valid, scale)
    want = ref.ref_attention(q, k, v, valid, scale)
    # rows/positions that are invalid are unspecified; compare valid region
    m = (valid[:, None, :, None] > 0.5)
    diff = jnp.abs(jnp.where(m, got - want, 0.0)).max()
    assert float(diff) < ATOL, float(diff)


@pytest.mark.parametrize("block_q,block_k", [(4, 4), (8, 16), (16, 8), (16, 16)])
def test_attention_block_shapes(block_q, block_k):
    r = _rng(0)
    b, h, t, dh = 2, 2, 32, 8
    q, k, v = (jnp.asarray(r.standard_normal((b, h, t, dh), np.float32)) for _ in range(3))
    valid = jnp.ones((b, t), jnp.float32)
    got = attention.attention(q, k, v, valid, 0.35, block_q=block_q, block_k=block_k)
    want = ref.ref_attention(q, k, v, valid, 0.35)
    assert float(jnp.abs(got - want).max()) < ATOL


def test_attention_fully_padded_rows_are_finite():
    r = _rng(1)
    b, h, t, dh = 2, 1, 16, 8
    q, k, v = (jnp.asarray(r.standard_normal((b, h, t, dh), np.float32)) for _ in range(3))
    valid = np.ones((b, t), np.float32)
    valid[0, :] = 0.0  # row with no valid keys at all
    got = attention.attention(q, k, v, jnp.asarray(valid), 0.35)
    assert bool(jnp.isfinite(got).all())


def test_attention_is_causal():
    """Changing a future token must not change past outputs."""
    r = _rng(2)
    b, h, t, dh = 1, 2, 16, 8
    q = jnp.asarray(r.standard_normal((b, h, t, dh), np.float32))
    k = np.asarray(r.standard_normal((b, h, t, dh), np.float32))
    v = np.asarray(r.standard_normal((b, h, t, dh), np.float32))
    valid = jnp.ones((b, t), jnp.float32)
    out1 = attention.attention(q, jnp.asarray(k), jnp.asarray(v), valid, 0.35)
    k2, v2 = k.copy(), v.copy()
    k2[:, :, 10:, :] += 5.0
    v2[:, :, 10:, :] -= 3.0
    out2 = attention.attention(q, jnp.asarray(k2), jnp.asarray(v2), valid, 0.35)
    assert float(jnp.abs(out1[:, :, :10] - out2[:, :, :10]).max()) < ATOL


# ---------------------------------------------------------------------------
# spec_accept
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    g=st.sampled_from([4, 16, 48]),
    loglen=st.sampled_from([-100.0, -0.5, 0.0, 0.5, 2.0, 100.0]),
    seed=st.integers(0, 2**16),
)
def test_spec_accept_matches_ref(b, g, loglen, seed):
    r = _rng(seed)
    lc = jnp.asarray((r.standard_normal((b, g)) - 1.5).astype(np.float32))
    lp = jnp.asarray((r.standard_normal((b, g)) - 1.5).astype(np.float32))
    u = jnp.asarray(r.random((b, g)).astype(np.float32))
    lens = r.integers(0, g + 1, b)
    dv = jnp.asarray((np.arange(g)[None, :] < lens[:, None]).astype(np.float32))
    rj1, la1 = ref.ref_spec_accept(lc, lp, u, dv, loglen)
    rj2, la2 = spec_accept.spec_accept(lc, lp, u, dv, loglen)
    assert (np.array(rj1) == np.array(rj2)).all()
    assert float(jnp.abs(la1 - la2).max()) < ATOL


def test_spec_accept_full_lenience_full_reuse():
    """l -> inf accepts every valid draft token (paper: full reuse)."""
    r = _rng(3)
    b, g = 4, 16
    lc = jnp.asarray((r.standard_normal((b, g)) - 5).astype(np.float32))
    lp = jnp.asarray((r.standard_normal((b, g))).astype(np.float32))
    u = jnp.asarray(np.full((b, g), 0.999999, np.float32))
    lens = np.array([0, 5, 16, 9])
    dv = jnp.asarray((np.arange(g)[None, :] < lens[:, None]).astype(np.float32))
    rj, _ = spec_accept.spec_accept(lc, lp, u, dv, 1e9)
    assert (np.array(rj) == lens).all()


def test_spec_accept_zero_lenience_rejects_at_zero():
    """l -> 0 rejects immediately (vanilla RLVR, no reuse)."""
    r = _rng(4)
    b, g = 4, 16
    lc = jnp.asarray(np.zeros((b, g), np.float32))
    lp = jnp.asarray(np.zeros((b, g), np.float32))
    u = jnp.asarray(np.full((b, g), 0.01, np.float32))
    dv = jnp.ones((b, g), jnp.float32)
    rj, _ = spec_accept.spec_accept(lc, lp, u, dv, -1e9)
    assert (np.array(rj) == 0).all()


def test_spec_accept_identity_policy_accepts_everything():
    """Same policy + l=1: ratio == 1 >= u for u<1, so full acceptance."""
    r = _rng(5)
    b, g = 8, 24
    lp = jnp.asarray((r.standard_normal((b, g)) - 2).astype(np.float32))
    u = jnp.asarray((r.random((b, g)) * 0.999).astype(np.float32))
    dv = jnp.ones((b, g), jnp.float32)
    rj, _ = spec_accept.spec_accept(lp, lp, u, dv, 0.0)
    assert (np.array(rj) == g).all()


def test_spec_accept_monotone_in_lenience():
    """E[reject offset] is non-decreasing in lenience."""
    r = _rng(6)
    b, g = 32, 48
    lc = jnp.asarray((r.standard_normal((b, g)) - 2).astype(np.float32))
    lp = jnp.asarray((r.standard_normal((b, g)) - 2).astype(np.float32))
    u = jnp.asarray(r.random((b, g)).astype(np.float32))
    dv = jnp.ones((b, g), jnp.float32)
    prev = -1.0
    for loglen in [-2.0, -0.5, 0.0, 0.5, 2.0, 9.0]:
        rj, _ = spec_accept.spec_accept(lc, lp, u, dv, loglen)
        mean = float(np.array(rj).mean())
        assert mean >= prev - 1e-9
        prev = mean


# ---------------------------------------------------------------------------
# logprob
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([8, 64, 256]),
    v=st.sampled_from([13, 52, 128]),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**16),
)
def test_logprob_matches_ref(n, v, scale, seed):
    r = _rng(seed)
    logits = jnp.asarray((r.standard_normal((n, v)) * scale).astype(np.float32))
    tgt = jnp.asarray(r.integers(0, v, n).astype(np.int32))
    l1, e1 = ref.ref_logprob(logits, tgt)
    l2, e2 = logprob.logprob(logits, tgt)
    assert float(jnp.abs(l1 - l2).max()) < ATOL * max(1.0, scale)
    assert float(jnp.abs(e1 - e2).max()) < ATOL * max(1.0, scale)


def test_logprob_is_normalized():
    """exp(logp) over all targets sums to 1 per row."""
    r = _rng(7)
    n, v = 4, 52
    logits = jnp.asarray((r.standard_normal((n, v)) * 2).astype(np.float32))
    total = np.zeros(n)
    for t in range(v):
        tgt = jnp.full((n,), t, jnp.int32)
        lp, _ = logprob.logprob(logits, tgt, block_n=4)
        total += np.exp(np.array(lp))
    assert np.abs(total - 1.0).max() < 1e-4


def test_logprob_entropy_bounds():
    """0 <= entropy <= log V; uniform logits hit the upper bound."""
    n, v = 8, 52
    logits = jnp.zeros((n, v), jnp.float32)
    _, ent = logprob.logprob(logits, jnp.zeros((n,), jnp.int32), block_n=8)
    assert np.allclose(np.array(ent), np.log(v), atol=1e-5)
    # peaked logits: entropy near zero
    peaked = jnp.zeros((n, v), jnp.float32).at[:, 3].set(50.0)
    _, ent2 = logprob.logprob(peaked, jnp.zeros((n,), jnp.int32), block_n=8)
    assert float(np.abs(np.array(ent2)).max()) < 1e-3
