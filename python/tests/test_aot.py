"""AOT pipeline tests: signatures, output-field maps, HLO text lowering.

Keeps the python->rust contract honest without running the full pipeline:
a single nano bundle is lowered to a temp dir and its manifest structure
checked field by field.
"""

import json
import os
import tempfile

import jax
import numpy as np
import pytest

from compile import aot, config as C, model as M

jax.config.update("jax_platform_name", "cpu")

GEO = C.SeqGeometry(prompt_len=8, total_len=24)


def test_entry_signatures_cover_all_entries():
    cfg = C.PRESETS["nano"]
    sigs = aot.entry_signatures(cfg, GEO, 4, value_head=False)
    assert set(sigs) == {
        "prefill", "decode", "refill", "read_gen", "read_metrics", "score",
        "verify", "verify_seat", "train_policy", "train_sft",
    }
    # every signature starts with the policy blob
    for name, sig in sigs.items():
        if name != "read_gen":
            assert sig[0]["name"] == "blob", name
            assert sig[0]["shape"] == [C.blob_size(cfg, GEO)], name


def test_critic_signatures():
    cfg = C.PRESETS["critic"]
    sigs = aot.entry_signatures(cfg, GEO, 4, value_head=True)
    assert set(sigs) == {"value_fwd", "train_value", "read_metrics"}


def test_output_fields_offsets_are_contiguous():
    cfg = C.PRESETS["nano"]
    for entry in ["prefill", "decode", "refill", "verify_seat", "read_gen",
                  "score", "verify", "train_policy"]:
        fields = aot.output_fields(entry, cfg, GEO, 4, False)
        off = 0
        for f in fields:
            assert f["offset"] == off, (entry, f)
            off += int(np.prod(f["shape"]))


def test_verify_output_layout_matches_rust_expectations():
    cfg = C.PRESETS["nano"]
    b, g = 4, GEO.gen_len
    fields = {f["name"]: f for f in aot.output_fields("verify", cfg, GEO, b, False)}
    assert fields["reject_off"]["offset"] == 0
    assert fields["logp"]["offset"] == b
    assert fields["entropy"]["offset"] == b + b * g


def test_gen_blob_and_read_gen_carry_aux_lane():
    cfg = C.PRESETS["nano"]
    b, v = 4, cfg.vocab
    spec = dict(C.gen_blob_spec(cfg, GEO, b))
    assert spec["aux"] == (b,)
    fields = {f["name"]: f for f in aot.output_fields("read_gen", cfg, GEO, b, False)}
    assert fields["probs"]["offset"] == 0
    assert fields["aux"]["offset"] == b * v
    seat = {f["name"]: f for f in aot.output_fields("verify_seat", cfg, GEO, b, False)}
    assert seat["aux"]["shape"] == [b]
    # entry output sizes match the gen blob spec exactly
    assert sum(int(np.prod(f["shape"])) for f in seat.values()) == C.flat_size(
        C.gen_blob_spec(cfg, GEO, b)
    )


@pytest.mark.slow
def test_lower_bundle_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        info = aot.lower_bundle("nano", 4, GEO, d, use_pallas=True, seed=3)
        # every entry wrote parseable-looking HLO text
        for name, e in info["entries"].items():
            path = os.path.join(d, e["file"])
            text = open(path).read()
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name
        # init blob loads and has the right size
        blob = np.load(os.path.join(d, info["init_blob"]))
        assert blob.shape == (info["blob_size"],)
        assert blob.dtype == np.float32
        # info JSON-serializable (manifest contract)
        json.dumps(info)


def test_pallas_attention_flag_changes_graph():
    """The perf build (jnp attention) and kernel build (pallas attention)
    must produce different HLO but identical numerics."""
    import jax.numpy as jnp

    cfg = C.PRESETS["nano"]
    b = 2
    e_fast = M.make_entries(cfg, GEO, b, use_pallas=True, pallas_attention=False)
    e_kern = M.make_entries(cfg, GEO, b, use_pallas=True, pallas_attention=True)
    blob = jnp.asarray(M.init_blob(0, cfg, GEO))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(3, cfg.vocab, (b, GEO.total_len)).astype(np.int32))
    valid = jnp.ones((b, GEO.total_len), jnp.float32)
    temp = jnp.asarray([1.0], jnp.float32)
    o1 = e_fast["score"](blob, tokens, valid, temp)
    o2 = e_kern["score"](blob, tokens, valid, temp)
    assert np.abs(np.asarray(o1) - np.asarray(o2)).max() < 1e-4
