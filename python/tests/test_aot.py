"""AOT pipeline tests: signatures, output-field maps, HLO text lowering.

Keeps the python->rust contract honest without running the full pipeline:
a single nano bundle is lowered to a temp dir and its manifest structure
checked field by field.
"""

import json
import os
import tempfile

import jax
import numpy as np
import pytest

from compile import aot, config as C, model as M

jax.config.update("jax_platform_name", "cpu")

GEO = C.SeqGeometry(prompt_len=8, total_len=24)


def test_entry_signatures_cover_all_entries():
    cfg = C.PRESETS["nano"]
    sigs = aot.entry_signatures(cfg, GEO, 4, value_head=False)
    assert set(sigs) == {
        "prefill", "decode", "refill", "read_gen", "read_metrics", "score",
        "verify", "verify_seat", "train_policy", "train_sft",
        "sample", "read_step",
    }
    # every signature starts with the policy blob, except the gen-blob-only
    # entries (readbacks + the device sampler, which never touch params)
    gen_first = {"read_gen", "read_step", "sample"}
    sg = C.flat_size(C.gen_blob_spec(cfg, GEO, 4))
    for name, sig in sigs.items():
        if name in gen_first:
            assert sig[0]["name"] == "gen", name
            assert sig[0]["shape"] == [sg], name
        else:
            assert sig[0]["name"] == "blob", name
            assert sig[0]["shape"] == [C.blob_size(cfg, GEO)], name


def test_critic_signatures():
    cfg = C.PRESETS["critic"]
    sigs = aot.entry_signatures(cfg, GEO, 4, value_head=True)
    assert set(sigs) == {"value_fwd", "train_value", "read_metrics"}


def test_output_fields_offsets_are_contiguous():
    cfg = C.PRESETS["nano"]
    for entry in ["prefill", "decode", "refill", "verify_seat", "sample",
                  "read_gen", "read_step", "score", "verify", "train_policy"]:
        fields = aot.output_fields(entry, cfg, GEO, 4, False)
        off = 0
        for f in fields:
            assert f["offset"] == off, (entry, f)
            off += int(np.prod(f["shape"]))


def test_verify_output_layout_matches_rust_expectations():
    cfg = C.PRESETS["nano"]
    b, g = 4, GEO.gen_len
    fields = {f["name"]: f for f in aot.output_fields("verify", cfg, GEO, b, False)}
    assert fields["reject_off"]["offset"] == 0
    assert fields["logp"]["offset"] == b
    assert fields["entropy"]["offset"] == b + b * g


def test_gen_blob_and_read_gen_carry_aux_lane():
    cfg = C.PRESETS["nano"]
    b, v = 4, cfg.vocab
    spec = dict(C.gen_blob_spec(cfg, GEO, b))
    assert spec["aux"] == (b,)
    fields = {f["name"]: f for f in aot.output_fields("read_gen", cfg, GEO, b, False)}
    assert fields["probs"]["offset"] == 0
    assert fields["aux"]["offset"] == b * v
    seat = {f["name"]: f for f in aot.output_fields("verify_seat", cfg, GEO, b, False)}
    assert seat["aux"]["shape"] == [b]
    # entry output sizes match the gen blob spec exactly
    assert sum(int(np.prod(f["shape"])) for f in seat.values()) == C.flat_size(
        C.gen_blob_spec(cfg, GEO, b)
    )


def test_gen_blob_out_lanes_and_read_step_layout():
    """PR 6 contract: the gen blob carries the live/tok/ptok out-lanes after
    aux, and read_step returns the fused [B tok | B ptok | B aux] payload."""
    cfg = C.PRESETS["nano"]
    b, v = 4, cfg.vocab
    spec = C.gen_blob_spec(cfg, GEO, b)
    names = [n for n, _ in spec]
    assert names[-4:] == ["aux", "live", "tok", "ptok"]
    assert dict(spec)["tok"] == (b,)
    fields = {f["name"]: f for f in aot.output_fields("read_step", cfg, GEO, b, False)}
    assert fields["tok"]["offset"] == 0
    assert fields["ptok"]["offset"] == b
    assert fields["aux"]["offset"] == 2 * b
    # the sample entry's output is the full gen blob, lanes included
    sample = {f["name"]: f for f in aot.output_fields("sample", cfg, GEO, b, False)}
    assert sample["live"]["shape"] == [b]
    assert sample["tok"]["offset"] + b == sample["ptok"]["offset"]
    assert sum(int(np.prod(f["shape"])) for f in sample.values()) == C.flat_size(spec)


def test_device_rng_stream_matches_host_reference():
    """The `sample` entry's uniforms replay the coordinator's per-task
    xoshiro256** streams bit-for-bit: jax's (hi, lo)-u32 emulation must
    agree with the pure-python u64 reference (which mirrors
    rust/src/util/rng.rs exactly) at every (nonce, id, draws)."""
    import jax.numpy as jnp

    from compile.kernels import xoshiro as X

    max_draws = GEO.gen_len
    for nonce in [0, 1, 0xDEAD_BEEF_CAFE_F00D, (1 << 64) - 1, 0x9E37_79B9_7F4A_7C15]:
        ids = np.array([0, 1, 7, 1000, 2**31 - 1], np.int32)
        draws = np.array([0, 1, max_draws, 3, max_draws - 1], np.int32)
        nonce_w = np.array(
            [(nonce >> 32) & 0xFFFF_FFFF, nonce & 0xFFFF_FFFF], np.uint32
        ).astype(np.int32)  # the i32 bit-split the rust side uploads
        dev = np.asarray(
            X.task_uniform(
                jnp.asarray(nonce_w[0]), jnp.asarray(nonce_w[1]),
                jnp.asarray(ids), jnp.asarray(draws), max_draws,
            )
        )
        ref = np.array(
            [X.ref_task_uniform(nonce, int(i), int(d)) for i, d in zip(ids, draws)],
            np.float32,
        )
        np.testing.assert_array_equal(dev, ref, err_msg=f"nonce {nonce:#x}")


def test_device_sampler_matches_host_top_p_bitwise():
    """device_sample must reproduce TopPSampler::sample exactly — including
    the prob-desc/index-asc tie-break and the sequential f32 mass sums —
    for both the categorical (top_p >= 1) and nucleus branches."""
    import jax.numpy as jnp

    from compile.kernels import xoshiro as X

    rng = np.random.default_rng(42)
    b, v = 8, 16
    for top_p in [1.0, 0.95, 0.8, 0.5]:
        probs = rng.random((b, v), np.float32)
        probs[0, 3] = probs[0, 11]  # force an exact tie
        probs[1] = 1.0 / v  # uniform row: every slot ties
        u01 = rng.random(b, np.float32)
        tok, ptok = X.device_sample(
            jnp.asarray(probs), jnp.asarray(u01), jnp.float32(top_p)
        )
        tok, ptok = np.asarray(tok), np.asarray(ptok)
        for r in range(b):
            want = X.ref_sample(probs[r], top_p, np.float32(u01[r]))
            assert tok[r] == want, f"top_p {top_p} row {r}: {tok[r]} != {want}"
            assert ptok[r] == probs[r, want], f"top_p {top_p} row {r}"


def test_sample_entry_pins_rng_stream_and_arming_modes():
    """End-to-end through the lowered-entry functions: `sample` writes the
    reference token/prob into the tok/ptok lanes for armed rows (mode 1
    always, mode 2 iff live), -1/0 otherwise, and `read_step` returns the
    fused [tok | ptok | aux] payload."""
    import jax.numpy as jnp

    from compile.kernels import xoshiro as X

    cfg = C.PRESETS["nano"]
    b, v = 4, cfg.vocab
    entries = M.make_entries(cfg, GEO, b, use_pallas=False)
    spec = C.gen_blob_spec(cfg, GEO, b)
    offs, off = {}, 0
    for name, shape in spec:
        offs[name] = off
        off += int(np.prod(shape))
    blob = np.zeros(off, np.float32)
    rng = np.random.default_rng(7)
    probs = rng.random((b, v), np.float32)
    blob[offs["probs"]:offs["probs"] + b * v] = probs.reshape(-1)
    aux = np.array([3.0, 0.0, 5.0, 1.0], np.float32)
    blob[offs["aux"]:offs["aux"] + b] = aux
    live = np.array([1.0, 0.0, 1.0, 0.0], np.float32)
    blob[offs["live"]:offs["live"] + b] = live

    nonce = 0xFEED_FACE_1234_5678
    top_p = 0.9
    # row 0: mode 2 + live -> armed; row 1: mode 2 + dead -> skipped;
    # row 2: mode 1 (decode survivor, 3 draws consumed); row 3: mode 0
    ctrl = np.array([[11, 0, 2], [12, 0, 2], [13, 3, 1], [14, 0, 0]], np.int32)
    nonce_w = np.array(
        [(nonce >> 32) & 0xFFFF_FFFF, nonce & 0xFFFF_FFFF], np.uint32
    ).astype(np.int32)
    out = np.asarray(entries["sample"](
        jnp.asarray(blob), jnp.asarray(ctrl), jnp.asarray(nonce_w),
        jnp.asarray([top_p], np.float32),
    ))
    step = np.asarray(entries["read_step"](jnp.asarray(out)))
    assert step.shape == (3 * b,)
    tok, ptok, aux_out = step[:b], step[b:2 * b], step[2 * b:]
    np.testing.assert_array_equal(aux_out, aux, err_msg="aux passes through")
    for r, armed in enumerate([True, False, True, False]):
        if not armed:
            assert tok[r] == -1.0 and ptok[r] == 0.0, f"row {r} must be unarmed"
            continue
        u = X.ref_task_uniform(nonce, int(ctrl[r, 0]), int(ctrl[r, 1]))
        want = X.ref_sample(probs[r], top_p, u)
        assert tok[r] == float(want), f"row {r}: {tok[r]} != {want}"
        assert ptok[r] == probs[r, want], f"row {r}"
    # the non-lane region (probs etc.) passes through untouched
    np.testing.assert_array_equal(out[:offs["aux"]], blob[:offs["aux"]])


@pytest.mark.slow
def test_lower_bundle_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        info = aot.lower_bundle("nano", 4, GEO, d, use_pallas=True, seed=3)
        # every entry wrote parseable-looking HLO text
        for name, e in info["entries"].items():
            path = os.path.join(d, e["file"])
            text = open(path).read()
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name
        # init blob loads and has the right size
        blob = np.load(os.path.join(d, info["init_blob"]))
        assert blob.shape == (info["blob_size"],)
        assert blob.dtype == np.float32
        # info JSON-serializable (manifest contract)
        json.dumps(info)


def test_pallas_attention_flag_changes_graph():
    """The perf build (jnp attention) and kernel build (pallas attention)
    must produce different HLO but identical numerics."""
    import jax.numpy as jnp

    cfg = C.PRESETS["nano"]
    b = 2
    e_fast = M.make_entries(cfg, GEO, b, use_pallas=True, pallas_attention=False)
    e_kern = M.make_entries(cfg, GEO, b, use_pallas=True, pallas_attention=True)
    blob = jnp.asarray(M.init_blob(0, cfg, GEO))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(3, cfg.vocab, (b, GEO.total_len)).astype(np.int32))
    valid = jnp.ones((b, GEO.total_len), jnp.float32)
    temp = jnp.asarray([1.0], jnp.float32)
    o1 = e_fast["score"](blob, tokens, valid, temp)
    o2 = e_kern["score"](blob, tokens, valid, temp)
    assert np.abs(np.asarray(o1) - np.asarray(o2)).max() < 1e-4
