"""L2 model invariants: decode/score consistency, blob round-trips, training.

These are the properties the SPEC-RL mechanism relies on:
- the incremental decode path and the teacher-forced score path induce the
  *same* distribution (otherwise speculative verification would not be
  faithful to the rollout policy);
- positional embeddings are addressed logically (left-padding invariance);
- a train step moves parameters and reports sane metrics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import config as C
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = C.PRESETS["tiny"]
GEO = C.SeqGeometry(prompt_len=8, total_len=24)
B = 4
P, T, G, V = GEO.prompt_len, GEO.total_len, GEO.gen_len, CFG.vocab


@pytest.fixture(scope="module")
def blob():
    b = M.init_blob(0, CFG, GEO)
    # randomize the head so the policy is non-uniform
    rng = np.random.default_rng(1)
    recs, _ = M.param_offsets(CFG, GEO)
    for name, off, shape in recs:
        if name == "head":
            n = int(np.prod(shape))
            b[off : off + n] = rng.standard_normal(n).astype(np.float32) * 0.2
    return jnp.asarray(b)


@pytest.fixture(scope="module")
def entries():
    return M.make_entries(CFG, GEO, B, use_pallas=True, critic_cfg=C.PRESETS["critic"])


@pytest.fixture(scope="module")
def ref_entries():
    return M.make_entries(CFG, GEO, B, use_pallas=False)


def make_prompts(seed=2):
    rng = np.random.default_rng(seed)
    plens = rng.integers(2, P, B)
    tokens = np.zeros((B, T), np.int32)
    valid = np.zeros((B, T), np.float32)
    for b in range(B):
        toks = rng.integers(3, V, plens[b])
        tokens[b, P - plens[b] : P] = toks
        valid[b, P - plens[b] : P] = 1
    return tokens, valid, plens


def greedy_rollout(entries, blob, tokens, valid, steps):
    """Greedy decode `steps` tokens; returns (tokens, valid, logps [B,steps]).

    The decode entry carries no [B, T] valid arg: the mask lives in the gen
    blob, extended device-side from `slot` (the host copy here only serves
    the teacher-forced cross-checks)."""
    temp = jnp.asarray([1.0], jnp.float32)
    last = jnp.full((B,), P - 1, jnp.int32)
    gen = entries["prefill"](blob, jnp.asarray(tokens), jnp.asarray(valid), last, temp)
    ck_n = CFG.n_layers * B * T * CFG.d_model
    probs = np.asarray(entries["read_gen"](gen))[: B * V].reshape(B, V)
    # [ck | cv | valid | probs | aux | live | tok | ptok]
    assert gen.shape[0] == 2 * ck_n + B * T + B * V + 4 * B
    toks, val = tokens.copy(), valid.copy()
    logps = []
    for j in range(steps):
        nxt = probs.argmax(1).astype(np.int32)
        logps.append(np.log(probs[np.arange(B), nxt] + 1e-30))
        slot = np.full((B,), P + j, np.int32)
        toks[:, P + j] = nxt
        val[:, P + j] = 1
        lpos = val.sum(1).astype(np.int32) - 1
        gen = entries["decode"](
            blob, gen, jnp.asarray(nxt), jnp.asarray(slot), jnp.asarray(lpos), temp,
        )
        # device-side mask must track the host-side one exactly
        dev_valid = np.asarray(gen[2 * ck_n : 2 * ck_n + B * T]).reshape(B, T)
        assert np.array_equal(dev_valid, val)
        probs = np.asarray(entries["read_gen"](gen))[: B * V].reshape(B, V)
    return toks, val, np.stack(logps, 1)


def test_decode_matches_score(entries, blob):
    """Incremental rollout logps == teacher-forced score logps (1e-4)."""
    tokens, valid, _ = make_prompts()
    toks, val, dec_lp = greedy_rollout(entries, blob, tokens, valid, 6)
    out = entries["score"](blob, jnp.asarray(toks), jnp.asarray(val), jnp.asarray([1.0], jnp.float32))
    lp = np.asarray(out[: B * G]).reshape(B, G)
    assert np.abs(lp[:, :6] - dec_lp).max() < 1e-4


def test_pallas_and_ref_entries_agree(entries, ref_entries, blob):
    """use_pallas=True and use_pallas=False score paths agree."""
    tokens, valid, _ = make_prompts()
    toks, val, _ = greedy_rollout(entries, blob, tokens, valid, 5)
    temp = jnp.asarray([1.0], jnp.float32)
    o1 = entries["score"](blob, jnp.asarray(toks), jnp.asarray(val), temp)
    o2 = ref_entries["score"](blob, jnp.asarray(toks), jnp.asarray(val), temp)
    lp1 = np.asarray(o1[: B * G]).reshape(B, G)
    lp2 = np.asarray(o2[: B * G]).reshape(B, G)
    m = np.asarray(val)[:, P:] > 0.5
    assert np.abs(np.where(m, lp1 - lp2, 0)).max() < 1e-4


def test_left_pad_shift_invariance(entries, blob):
    """Shifting a prompt deeper into the pad region must not change probs
    (logical positions are mask-derived)."""
    rng = np.random.default_rng(3)
    ptoks = rng.integers(3, V, 4)
    temp = jnp.asarray([1.0], jnp.float32)
    probs = []
    for extra in [0, 2]:
        tokens = np.zeros((B, T), np.int32)
        valid = np.zeros((B, T), np.float32)
        start = P - len(ptoks)
        tokens[:, start:P] = ptoks
        valid[:, start:P] = 1
        if extra:
            # physically different: roll the whole prompt left by `extra`
            tokens = np.roll(tokens, -extra, axis=1)
            valid = np.roll(valid, -extra, axis=1)
        last = np.full((B,), P - 1 - extra, np.int32)
        gen = entries["prefill"](blob, jnp.asarray(tokens), jnp.asarray(valid),
                                 jnp.asarray(last), temp)
        probs.append(np.asarray(entries["read_gen"](gen))[: B * V].reshape(B, V))
    assert np.abs(probs[0] - probs[1]).max() < 1e-5


def unpack_gen_np(gen):
    """Split a flat gen blob into (ck, cv, valid, probs, aux) numpy views
    (the trailing live/tok/ptok out-lanes are dropped — the sample-entry
    tests in test_aot.py cover them)."""
    ck_n = CFG.n_layers * B * T * CFG.d_model
    ck = np.asarray(gen[:ck_n]).reshape(CFG.n_layers, B, T, CFG.d_model)
    cv = np.asarray(gen[ck_n : 2 * ck_n]).reshape(CFG.n_layers, B, T, CFG.d_model)
    vm = np.asarray(gen[2 * ck_n : 2 * ck_n + B * T]).reshape(B, T)
    pr = np.asarray(gen[2 * ck_n + B * T : 2 * ck_n + B * T + B * V]).reshape(B, V)
    base = 2 * ck_n + B * T + B * V
    aux = np.asarray(gen[base : base + B])
    return ck, cv, vm, pr, aux


def test_refill_rebuilds_masked_rows_and_preserves_live_rows(entries, blob):
    """refill == prefill for masked rows, bit-identical no-op for others."""
    tokens_a, valid_a, _ = make_prompts(seed=2)
    tokens_b, valid_b, _ = make_prompts(seed=9)
    temp = jnp.asarray([1.0], jnp.float32)
    last = jnp.full((B,), P - 1, jnp.int32)
    gen_a = entries["prefill"](blob, jnp.asarray(tokens_a), jnp.asarray(valid_a), last, temp)
    gen_b = entries["prefill"](blob, jnp.asarray(tokens_b), jnp.asarray(valid_b), last, temp)
    rowmask = np.array([1.0, 0.0, 1.0, 0.0], np.float32)
    gen_r = entries["refill"](
        blob, gen_a, jnp.asarray(tokens_b), jnp.asarray(valid_b),
        jnp.asarray(rowmask), last, temp,
    )
    a = unpack_gen_np(gen_a)
    bb = unpack_gen_np(gen_b)
    rr = unpack_gen_np(gen_r)
    for r in range(B):
        want = bb if rowmask[r] > 0.5 else a
        assert np.array_equal(rr[0][:, r], want[0][:, r]), f"cache_k row {r}"
        assert np.array_equal(rr[1][:, r], want[1][:, r]), f"cache_v row {r}"
        assert np.array_equal(rr[2][r], want[2][r]), f"valid row {r}"
        assert np.array_equal(rr[3][r], want[3][r]), f"probs row {r}"


def test_decode_out_of_range_slot_is_inert(entries, blob):
    """slot == T must leave a row's device-side valid mask untouched."""
    tokens, valid, _ = make_prompts()
    temp = jnp.asarray([1.0], jnp.float32)
    last = jnp.full((B,), P - 1, jnp.int32)
    gen = entries["prefill"](blob, jnp.asarray(tokens), jnp.asarray(valid), last, temp)
    nxt = np.full((B,), 5, np.int32)
    slot = np.array([P, T, P, T], np.int32)  # rows 1 and 3 inert
    lpos = valid.sum(1).astype(np.int32)
    gen2 = entries["decode"](
        blob, gen, jnp.asarray(nxt), jnp.asarray(slot), jnp.asarray(lpos), temp,
    )
    vm = unpack_gen_np(gen2)[2]
    expect = valid.copy()
    expect[0, P] = 1
    expect[2, P] = 1
    assert np.array_equal(vm, expect)


def test_verify_accepts_own_rollout(entries, blob):
    """Drafts sampled from the same policy w/ l=e^0.05 are fully accepted."""
    tokens, valid, _ = make_prompts()
    toks, val, dec_lp = greedy_rollout(entries, blob, tokens, valid, 6)
    rng = np.random.default_rng(4)
    dv = np.zeros((B, G), np.float32)
    dv[:, :6] = 1
    logp_prev = np.zeros((B, G), np.float32)
    logp_prev[:, :6] = dec_lp
    u = rng.random((B, G)).astype(np.float32) * 0.999
    out = entries["verify"](
        blob, jnp.asarray(toks), jnp.asarray(val), jnp.asarray(logp_prev),
        jnp.asarray(u), jnp.asarray(dv), jnp.asarray([0.05], jnp.float32),
        jnp.asarray([1.0], jnp.float32),
    )
    rej = np.asarray(out[:B]).astype(int)
    assert (rej == 6).all(), rej


def test_verify_zero_lenience_rejects_all(entries, blob):
    tokens, valid, _ = make_prompts()
    toks, val, dec_lp = greedy_rollout(entries, blob, tokens, valid, 4)
    dv = np.zeros((B, G), np.float32)
    dv[:, :4] = 1
    lp_prev = np.zeros((B, G), np.float32)
    lp_prev[:, :4] = dec_lp
    u = np.full((B, G), 0.5, np.float32)
    out = entries["verify"](
        blob, jnp.asarray(toks), jnp.asarray(val), jnp.asarray(lp_prev),
        jnp.asarray(u), jnp.asarray(dv), jnp.asarray([-1e9], jnp.float32),
        jnp.asarray([1.0], jnp.float32),
    )
    rej = np.asarray(out[:B]).astype(int)
    assert (rej == 0).all(), rej


def test_verify_seat_equals_verify_then_refill(entries, blob):
    """verify_seat must agree with the two-phase oracle: same rejection
    offsets as `verify`, and (for masked rows) the same seated probs/valid
    as a `refill` over the truncated accepted prefix. Unmasked rows keep
    their state bit-for-bit."""
    tokens, valid, plens = make_prompts()
    toks, val, dec_lp = greedy_rollout(entries, blob, tokens, valid, 6)
    temp = jnp.asarray([1.0], jnp.float32)
    loglen = jnp.asarray([0.0], jnp.float32)
    dv = np.zeros((B, G), np.float32)
    dv[:, :6] = 1
    lp_prev = np.zeros((B, G), np.float32)
    lp_prev[:, :6] = dec_lp + np.linspace(0.0, 1.5, 6)[None, :]  # force mid-draft rejects
    rng = np.random.default_rng(11)
    u = rng.random((B, G)).astype(np.float32)

    out = entries["verify"](
        blob, jnp.asarray(toks), jnp.asarray(val), jnp.asarray(lp_prev),
        jnp.asarray(u), jnp.asarray(dv), loglen, temp,
    )
    rej = np.asarray(out[:B]).astype(int)
    assert rej.min() < 6, "want at least one mid-draft rejection"

    # seed a gen state from other prompts, then verify_seat rows 0 and 2
    tokens_b, valid_b, _ = make_prompts(seed=9)
    last_b = jnp.full((B,), P - 1, jnp.int32)
    gen0 = entries["prefill"](blob, jnp.asarray(tokens_b), jnp.asarray(valid_b), last_b, temp)
    rowmask = np.array([1.0, 0.0, 1.0, 0.0], np.float32)
    gen_s = entries["verify_seat"](
        blob, gen0, jnp.asarray(toks), jnp.asarray(val), jnp.asarray(lp_prev),
        jnp.asarray(u), jnp.asarray(dv), jnp.asarray(rowmask), loglen, temp,
    )
    # the refill oracle: truncate each draft to its accepted prefix
    toks_acc, val_acc = toks.copy(), val.copy()
    for r in range(B):
        toks_acc[r, P + rej[r] :] = 0
        val_acc[r, P + rej[r] :] = 0
    last_acc = jnp.asarray(P + rej - 1, jnp.int32)
    gen_r = entries["refill"](
        blob, gen0, jnp.asarray(toks_acc), jnp.asarray(val_acc),
        jnp.asarray(rowmask), last_acc, temp,
    )
    s, rr, g0 = unpack_gen_np(gen_s), unpack_gen_np(gen_r), unpack_gen_np(gen0)
    assert np.array_equal(s[4][rowmask > 0.5], rej[rowmask > 0.5].astype(np.float32))
    assert np.array_equal(s[4][rowmask < 0.5], g0[4][rowmask < 0.5]), "aux passthrough"
    for r in range(B):
        if rowmask[r] < 0.5:
            for i in range(4):
                want = g0[i][:, r] if i < 2 else g0[i][r]
                got = s[i][:, r] if i < 2 else s[i][r]
                assert np.array_equal(got, want), f"unmasked row {r} field {i}"
            continue
        assert np.array_equal(s[2][r], rr[2][r]), f"valid row {r}"
        assert np.abs(s[3][r] - rr[3][r]).max() < 1e-5, f"probs row {r}"
        # KV at accepted (valid) positions matches the truncated refill;
        # rejected positions are masked out and may hold garbage
        keep = val_acc[r] > 0.5
        assert np.abs(s[0][:, r][:, keep] - rr[0][:, r][:, keep]).max() < 1e-5
        assert np.abs(s[1][:, r][:, keep] - rr[1][:, r][:, keep]).max() < 1e-5


def test_train_policy_moves_params_and_reports_metrics(entries, blob):
    tokens, valid, _ = make_prompts()
    toks, val, dec_lp = greedy_rollout(entries, blob, tokens, valid, 6)
    rng = np.random.default_rng(5)
    rm = np.zeros((B, G), np.float32)
    rm[:, :6] = 1
    adv = rng.standard_normal((B, G)).astype(np.float32) * rm
    old_lp = np.zeros((B, G), np.float32)
    old_lp[:, :6] = dec_lp
    hp = jnp.asarray([1e-3, 0.2, 0.2, 1e-3, 0.0, 1.0, 0.01, 1.0], jnp.float32)
    out = entries["train_policy"](
        blob, jnp.asarray(toks), jnp.asarray(val), jnp.asarray(rm),
        jnp.asarray(adv), jnp.asarray(old_lp), jnp.asarray(old_lp), hp,
    )
    n = C.n_params(CFG, GEO)
    assert float(jnp.abs(out[:n] - blob[:n]).max()) > 0
    step = float(out[3 * n])
    metrics = np.asarray(out[3 * n + 1 :])
    assert step == 1.0
    assert np.isfinite(metrics).all()
    # same policy => ratio ~= 1, kl ~= 0, clip_frac ~= 0
    assert abs(metrics[6] - 1.0) < 1e-3   # ratio_mean
    assert abs(metrics[2]) < 1e-5         # kl
    assert metrics[4] < 1e-6              # clip_frac
    assert metrics[7] == 24.0             # token_count = 4 rows * 6 tokens


def test_train_sft_reduces_loss(entries, blob):
    """A few SFT steps on a fixed batch must reduce the loss."""
    rng = np.random.default_rng(6)
    tokens = np.zeros((B, T), np.int32)
    valid = np.ones((B, T), np.float32)
    tokens[:, :] = rng.integers(3, V, (B, T))
    lm = np.ones((B, T), np.float32)
    hp = jnp.asarray([1e-2, 0.2, 0.2, 0.0, 0.0, 1.0, 0.0, 10.0], jnp.float32)
    cur = blob
    losses = []
    n = C.n_params(CFG, GEO)
    for _ in range(5):
        cur = entries["train_sft"](cur, jnp.asarray(tokens), jnp.asarray(valid),
                                   jnp.asarray(lm), hp)
        losses.append(float(cur[3 * n + 1]))
    assert losses[-1] < losses[0], losses


def test_value_entries(entries):
    vblob = jnp.asarray(M.init_blob(7, C.PRESETS["critic"], GEO, value_head=True))
    tokens, valid, _ = make_prompts()
    vals = entries["value_fwd"](vblob, jnp.asarray(tokens), jnp.asarray(valid))
    assert vals.shape == (B * (G + 1),)
    rm = np.zeros((B, G), np.float32)
    rm[:, :4] = 1
    tg = np.full((B, G), 0.7, np.float32)
    hp = jnp.asarray([1e-2, 0, 0, 0, 0, 1.0, 0.0, 10.0], jnp.float32)
    cur = vblob
    nv = C.n_params(C.PRESETS["critic"], GEO, True)
    losses = []
    for _ in range(8):
        cur = entries["train_value"](cur, jnp.asarray(tokens), jnp.asarray(valid),
                                     jnp.asarray(rm), jnp.asarray(tg), hp)
        losses.append(float(cur[3 * nv + 1]))
    assert losses[-1] < losses[0]


def test_blob_roundtrip():
    b = M.init_blob(8, CFG, GEO)
    p = M.params_from_flat(jnp.asarray(b[: C.n_params(CFG, GEO)]), CFG, GEO)
    flat = M.params_to_flat(p, CFG, GEO)
    assert np.abs(np.asarray(flat) - b[: C.n_params(CFG, GEO)]).max() == 0


def test_init_blob_deterministic():
    assert np.array_equal(M.init_blob(42, CFG, GEO), M.init_blob(42, CFG, GEO))
    assert not np.array_equal(M.init_blob(42, CFG, GEO), M.init_blob(43, CFG, GEO))
