"""L2: the policy/value transformer and every AOT entry point.

A decoder-only transformer (RMSNorm, fused-QKV attention, GeLU MLP,
learned positional embeddings addressed by *logical* position) defined as
pure functions over a flat f32 parameter blob.

Blob discipline (see DESIGN.md): the PJRT runtime in this image returns
multi-output executables as a single tuple buffer, which would force a
host round-trip per call to split. Every entry point therefore consumes
and produces **single flat f32 arrays**:

- ``policy blob``  = [params | adam_m | adam_v | step | metrics16]
- ``gen blob``     = [cache_k | cache_v | valid | probs | aux | live | tok | ptok]
- ``score/verify`` = [logp | entropy | ...]

so parameters, optimizer state and the KV cache stay device-resident
across calls; the rust coordinator reads sub-ranges (probs, metrics) via
raw host copies at manifest-recorded offsets.

Canonical sequence layout (all entry points): slots ``[0, P)`` hold the
right-aligned, left-padded prompt; slots ``[P, T)`` hold the response.
``valid[b, t]`` flags real tokens. Positional embeddings use the logical
position ``cumsum(valid) - 1`` so physical padding never shifts positions
(the vLLM/HF left-padding trick, which is what makes the paper's
"verified prefixes aligned via left padding" sound).

Attention in the batched scoring paths runs through the L1 Pallas kernel
(``use_pallas=True``); the training graphs use the jnp oracle because
gradients must flow (pallas interpret-mode has no registered VJP), and the
single-position decode path uses plain jnp (memory-bound, no tiling to
exploit). This split is deliberate and documented in DESIGN.md §Perf.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import config as C
from .kernels import attention as attn_k
from .kernels import logprob as logprob_k
from .kernels import ref as kref
from .kernels import spec_accept as accept_k
from .kernels import xoshiro as rng_k

EPS = 1e-6


# --------------------------------------------------------------------------
# blob plumbing
# --------------------------------------------------------------------------
def param_offsets(cfg: C.ModelConfig, geo: C.SeqGeometry, value_head: bool = False):
    """Cumulative (name, offset, shape) records for the parameter section."""
    recs = []
    off = 0
    for name, shape in C.param_layout(cfg, geo, value_head):
        n = 1
        for d in shape:
            n *= d
        recs.append((name, off, shape))
        off += n
    return recs, off


def params_from_flat(flat, cfg, geo, value_head=False) -> Dict[str, jnp.ndarray]:
    recs, _ = param_offsets(cfg, geo, value_head)
    out = {}
    for name, off, shape in recs:
        n = 1
        for d in shape:
            n *= d
        out[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)
    return out


def params_to_flat(params: Dict[str, jnp.ndarray], cfg, geo, value_head=False):
    recs, _ = param_offsets(cfg, geo, value_head)
    return jnp.concatenate([params[name].reshape(-1) for name, _, _ in recs])


def split_blob(blob, cfg, geo, value_head=False):
    """blob -> (params_flat, m_flat, v_flat, step, metrics)."""
    np_ = C.n_params(cfg, geo, value_head)
    p = blob[:np_]
    m = blob[np_ : 2 * np_]
    v = blob[2 * np_ : 3 * np_]
    step = blob[3 * np_]
    metrics = blob[3 * np_ + 1 :]
    return p, m, v, step, metrics


def join_blob(p, m, v, step, metrics):
    return jnp.concatenate([p, m, v, step.reshape(1), metrics])


def init_blob(key, cfg: C.ModelConfig, geo: C.SeqGeometry, value_head=False):
    """Initial policy blob: trunc-normal weights, zeroed head/optimizer.

    The lm head starts at zero so the initial policy is uniform — a clean
    exploration start for SFT and a well-defined base model.
    """
    import numpy as np

    rng = np.random.default_rng(int(key))
    parts = []
    for name, shape in C.param_layout(cfg, geo, value_head):
        n = 1
        for d in shape:
            n *= d
        if name.endswith("ln1") or name.endswith("ln2") or name == "ln_f":
            arr = np.ones(n, dtype=np.float32)
        elif name == "head":
            arr = np.zeros(n, dtype=np.float32)
        else:
            arr = (rng.standard_normal(n) * 0.02).astype(np.float32)
        parts.append(arr)
    p = np.concatenate(parts)
    np_total = p.shape[0]
    blob = np.concatenate(
        [p, np.zeros(2 * np_total + 1 + C.NUM_METRICS, dtype=np.float32)]
    )
    return blob


# --------------------------------------------------------------------------
# transformer forward
# --------------------------------------------------------------------------
def rmsnorm(x, scale):
    return x * scale * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS)


def forward_full(params, tokens, valid, cfg: C.ModelConfig, geo: C.SeqGeometry,
                 use_pallas: bool, value_head: bool = False):
    """Teacher-forced forward over the canonical [B, T] layout.

    Returns ``(logits [B,T,out], cache_k [L,B,T,D], cache_v [L,B,T,D])``.
    """
    b, t = tokens.shape
    d = cfg.d_model
    h = cfg.n_heads
    dh = cfg.d_head

    lpos = jnp.clip(jnp.cumsum(valid, axis=1).astype(jnp.int32) - 1, 0, t - 1)
    x = params["tok_emb"][tokens] + params["pos_emb"][lpos]
    x = x * valid[..., None]  # keep pad slots numerically clean

    cache_k: List[jnp.ndarray] = []
    cache_v: List[jnp.ndarray] = []
    scale = 1.0 / (dh ** 0.5)
    for l in range(cfg.n_layers):
        xn = rmsnorm(x, params[f"l{l}.ln1"])
        qkv = xn @ params[f"l{l}.wqkv"]  # [B,T,3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        cache_k.append(k)
        cache_v.append(v)
        qh = q.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        kh = k.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        vh = v.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        if use_pallas:
            oh = attn_k.attention(qh, kh, vh, valid, scale)
        else:
            oh = kref.ref_attention(qh, kh, vh, valid, scale)
        o = oh.transpose(0, 2, 1, 3).reshape(b, t, d)
        x = x + o @ params[f"l{l}.wo"]
        xn = rmsnorm(x, params[f"l{l}.ln2"])
        x = x + jax.nn.gelu(xn @ params[f"l{l}.w1"]) @ params[f"l{l}.w2"]

    xf = rmsnorm(x, params["ln_f"])
    logits = xf @ params["head"]
    ck = jnp.stack(cache_k, axis=0)
    cv = jnp.stack(cache_v, axis=0)
    return logits, ck, cv


def decode_one(params, cache_k, cache_v, token, slot, lpos, valid, temp,
               cfg: C.ModelConfig, geo: C.SeqGeometry):
    """One incremental decode step at per-row physical slots.

    token: i32[B] new token ids; slot: i32[B] physical write slot;
    lpos: i32[B] logical position of the new token; valid: f32[B,T]
    *including* the new token's slot. Returns (probs [B,V], ck', cv').
    """
    b = token.shape[0]
    t = geo.total_len
    d = cfg.d_model
    h = cfg.n_heads
    dh = cfg.d_head

    x = params["tok_emb"][token] + params["pos_emb"][jnp.clip(lpos, 0, t - 1)]  # [B,D]
    oh_slot = jax.nn.one_hot(slot, t, dtype=jnp.float32)  # [B,T]
    scale = 1.0 / (dh ** 0.5)

    new_ck, new_cv = [], []
    for l in range(cfg.n_layers):
        xn = rmsnorm(x, params[f"l{l}.ln1"])
        qkv = xn @ params[f"l{l}.wqkv"]  # [B,3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        ck = cache_k[l] * (1.0 - oh_slot[..., None]) + k[:, None, :] * oh_slot[..., None]
        cv = cache_v[l] * (1.0 - oh_slot[..., None]) + v[:, None, :] * oh_slot[..., None]
        new_ck.append(ck)
        new_cv.append(cv)
        qh = q.reshape(b, h, 1, dh)
        kh = ck.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        vh = cv.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) * scale  # [B,H,1,T]
        mask = valid[:, None, None, :] > 0.5
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", probs, vh).reshape(b, d)
        x = x + o @ params[f"l{l}.wo"]
        xn = rmsnorm(x, params[f"l{l}.ln2"])
        x = x + jax.nn.gelu(xn @ params[f"l{l}.w1"]) @ params[f"l{l}.w2"]

    xf = rmsnorm(x, params["ln_f"])
    logits = (xf @ params["head"]) / jnp.maximum(temp, 1e-4)
    probs = jax.nn.softmax(logits, axis=-1)
    return probs, jnp.stack(new_ck, 0), jnp.stack(new_cv, 0)


# --------------------------------------------------------------------------
# scoring helpers
# --------------------------------------------------------------------------
def response_logp_ent(logits, tokens, valid, temp, cfg, geo, use_pallas):
    """Per-response-token logp/entropy from full-sequence logits.

    Response token j (slot P+j) is predicted by logits at slot P+j-1.
    Returns (logp [B,G], ent [B,G]) — garbage where invalid; callers mask.
    """
    p, t = geo.prompt_len, geo.total_len
    g = geo.gen_len
    b = tokens.shape[0]
    pred = logits[:, p - 1 : t - 1, :] / jnp.maximum(temp, 1e-4)  # [B,G,V]
    tgt = tokens[:, p:t]  # [B,G]
    flat_logits = pred.reshape(b * g, cfg.vocab)
    flat_tgt = tgt.reshape(b * g)
    if use_pallas:
        lp, ent = logprob_k.logprob(flat_logits, flat_tgt)
    else:
        lp, ent = kref.ref_logprob(flat_logits, flat_tgt)
    return lp.reshape(b, g), ent.reshape(b, g)


# --------------------------------------------------------------------------
# entry points (each returns ONE flat f32 array)
# --------------------------------------------------------------------------
def make_entries(cfg: C.ModelConfig, geo: C.SeqGeometry, batch: int,
                 use_pallas: bool = True, critic_cfg: C.ModelConfig | None = None,
                 pallas_attention: bool | None = None):
    """Build all jit-able entry functions for one (model, geometry, batch).

    Returns a dict name -> (fn, example_args_spec) consumed by aot.py.
    """
    t, p, g = geo.total_len, geo.prompt_len, geo.gen_len
    b, v = batch, cfg.vocab
    # `use_pallas` gates the cheap fused kernels (spec_accept, logprob);
    # `pallas_attention` gates the attention kernel separately — on CPU the
    # interpret-mode attention is ~6x slower than the jnp oracle (see
    # EXPERIMENTS.md §Perf), so the perf build keeps attention on jnp while
    # the acceptance scan stays a Pallas kernel.
    attn_pallas = use_pallas if pallas_attention is None else pallas_attention
    gen_fields = C.gen_blob_spec(cfg, geo, b)
    np_pol = C.n_params(cfg, geo, False)

    def unpack_gen(gen_blob):
        out = {}
        off = 0
        for name, shape in gen_fields:
            n = 1
            for dim in shape:
                n *= dim
            out[name] = jax.lax.dynamic_slice(gen_blob, (off,), (n,)).reshape(shape)
            off += n
        return out

    def pack_gen(ck, cv, valid, probs, aux, live, tok, ptok):
        return jnp.concatenate(
            [ck.reshape(-1), cv.reshape(-1), valid.reshape(-1), probs.reshape(-1),
             aux.reshape(-1), live.reshape(-1), tok.reshape(-1), ptok.reshape(-1)]
        )

    def policy_params(blob):
        return params_from_flat(blob[:np_pol], cfg, geo, False)

    def gather_last_probs(logits, last, temp):
        """Next-token probs gathered at each row's `last` real slot."""
        lrow = jnp.clip(last, 0, t - 1)
        lg = jnp.take_along_axis(logits, lrow[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        lg = lg / jnp.maximum(temp[0], 1e-4)
        return jax.nn.softmax(lg, axis=-1)

    # -- prefill ------------------------------------------------------------
    def prefill(blob, tokens, valid, last, temp):
        """Build the KV cache over the canonical layout and seed the
        device-side valid mask; emit next-token probs at each row's `last`
        (per-row last real slot). This is the only [B, T] mask upload of a
        generation — decode maintains the mask on device."""
        params = policy_params(blob)
        logits, ck, cv = forward_full(params, tokens, valid, cfg, geo, attn_pallas)
        probs = gather_last_probs(logits, last, temp)
        zero = jnp.zeros((b,), jnp.float32)
        return pack_gen(ck, cv, valid, probs, zero, zero, zero, zero)

    # -- decode -------------------------------------------------------------
    def decode(blob, gen_blob, token, slot, lpos, temp):
        """One incremental step. The valid mask lives in the gen blob and is
        extended here by a one-hot write at `slot` (an out-of-range slot is
        an inert row: `one_hot` yields a zero row, nothing changes)."""
        params = policy_params(blob)
        gs = unpack_gen(gen_blob)
        oh_slot = jax.nn.one_hot(slot, t, dtype=jnp.float32)  # [B,T]
        valid = jnp.maximum(gs["valid"], oh_slot)
        probs, ck, cv = decode_one(
            params, gs["cache_k"], gs["cache_v"], token, slot, lpos, valid,
            temp[0], cfg, geo,
        )
        return pack_gen(ck, cv, valid, probs, gs["aux"], gs["live"], gs["tok"],
                        gs["ptok"])

    # -- refill: masked per-row (re)prefill into live generation state ------
    def refill(blob, gen_blob, tokens, valid, rowmask, last, temp):
        """Recompute cache/valid/probs for the rows flagged by `rowmask`
        (several freed slots batch into one call); untouched rows keep
        their state bit-for-bit. This is how the continuous scheduler
        re-seats a finished slot without stalling its neighbours."""
        params = policy_params(blob)
        gs = unpack_gen(gen_blob)
        logits, ck_new, cv_new = forward_full(params, tokens, valid, cfg, geo, attn_pallas)
        probs_new = gather_last_probs(logits, last, temp)
        m_row = rowmask[:, None]                 # [B,1]
        m_cache = rowmask[None, :, None, None]   # [1,B,1,1] over [L,B,T,D]
        ck = gs["cache_k"] * (1.0 - m_cache) + ck_new * m_cache
        cv = gs["cache_v"] * (1.0 - m_cache) + cv_new * m_cache
        vmask = gs["valid"] * (1.0 - m_row) + valid * m_row
        probs = gs["probs"] * (1.0 - m_row) + probs_new * m_row
        return pack_gen(ck, cv, vmask, probs, gs["aux"], gs["live"], gs["tok"],
                        gs["ptok"])

    # -- score --------------------------------------------------------------
    def score(blob, tokens, valid, temp):
        params = policy_params(blob)
        logits, _, _ = forward_full(params, tokens, valid, cfg, geo, attn_pallas)
        lp, ent = response_logp_ent(logits, tokens, valid, temp[0], cfg, geo, use_pallas)
        return jnp.concatenate([lp.reshape(-1), ent.reshape(-1)])

    # -- verify (the paper's Algorithm 1, one engine call) -------------------
    def verify(blob, tokens, valid, logp_prev, uniforms, draft_valid, loglen, temp):
        params = policy_params(blob)
        logits, _, _ = forward_full(params, tokens, valid, cfg, geo, attn_pallas)
        lp, ent = response_logp_ent(logits, tokens, valid, temp[0], cfg, geo, use_pallas)
        if use_pallas:
            rej, _ = accept_k.spec_accept(lp, logp_prev, uniforms, draft_valid, loglen[0])
        else:
            rej, _ = kref.ref_spec_accept(lp, logp_prev, uniforms, draft_valid, loglen[0])
        return jnp.concatenate(
            [rej.astype(jnp.float32), lp.reshape(-1), ent.reshape(-1)]
        )

    # -- verify_seat: verification folded into the slot pool ------------------
    def verify_seat(blob, gen_blob, tokens, valid, logp_prev, uniforms,
                    draft_valid, rowmask, loglen, temp):
        """Verify drafts *and* seat the accepted prefixes into the live
        generation state in one call (the phase-aware pipeline's Verify
        phase). The teacher-forced forward that scores the draft already
        computes exactly the KV cache the continuation needs: causal masked
        attention means activations (and KV) at every position <= the last
        accepted slot are identical to a refill over the truncated prefix,
        and KV at rejected positions is masked out by the truncated valid
        mask. So a verified row transitions Verify -> Decode without a
        second prefill forward — that is the device-call saving over the
        two-phase path. Rows named by `rowmask` are replaced; others keep
        their state bit-for-bit. Each seated row's accepted-prefix length
        is reported in the gen blob's `aux` lane (read back via read_gen).
        """
        params = policy_params(blob)
        gs = unpack_gen(gen_blob)
        logits, ck_new, cv_new = forward_full(params, tokens, valid, cfg, geo, attn_pallas)
        lp, _ent = response_logp_ent(logits, tokens, valid, temp[0], cfg, geo, use_pallas)
        if use_pallas:
            rej, _ = accept_k.spec_accept(lp, logp_prev, uniforms, draft_valid, loglen[0])
        else:
            rej, _ = kref.ref_spec_accept(lp, logp_prev, uniforms, draft_valid, loglen[0])
        # truncate each row's valid mask at its first rejection: response
        # position j survives iff j < rej (prompt region is untouched)
        jpos = jnp.arange(g, dtype=jnp.int32)[None, :]          # [1,G]
        keep = (jpos < rej[:, None]).astype(jnp.float32)        # [B,G]
        acc_valid = jnp.concatenate(
            [valid[:, :p], valid[:, p:] * keep], axis=1
        )
        last = (p + rej - 1).astype(jnp.int32)                  # rej=0 -> last prompt slot
        probs_new = gather_last_probs(logits, last, temp)
        m_row = rowmask[:, None]
        m_cache = rowmask[None, :, None, None]
        ck = gs["cache_k"] * (1.0 - m_cache) + ck_new * m_cache
        cv = gs["cache_v"] * (1.0 - m_cache) + cv_new * m_cache
        vmask = gs["valid"] * (1.0 - m_row) + acc_valid * m_row
        probs = gs["probs"] * (1.0 - m_row) + probs_new * m_row
        aux = gs["aux"] * (1.0 - rowmask) + rej.astype(jnp.float32) * rowmask
        # device-side termination flag for the `sample` entry (§12): a
        # seated row is live iff its accepted prefix is not yet terminal —
        # the same predicate the host's resolve_verified applies (accepted
        # length reached gen_len, or the last accepted token is EOS)
        last_tok = jnp.take_along_axis(
            tokens, jnp.clip(p + rej - 1, 0, t - 1)[:, None].astype(jnp.int32),
            axis=1,
        )[:, 0]
        ends_eos = jnp.logical_and(rej > 0, last_tok == C.EOS_ID)
        terminal = jnp.logical_or(rej >= g, ends_eos)
        live_new = 1.0 - terminal.astype(jnp.float32)
        live = gs["live"] * (1.0 - rowmask) + live_new * rowmask
        return pack_gen(ck, cv, vmask, probs, aux, live, gs["tok"], gs["ptok"])

    # -- losses ---------------------------------------------------------------
    def policy_loss(pflat, tokens, valid, resp_mask, adv, old_logp, ref_logp, hp):
        params = params_from_flat(pflat, cfg, geo, False)
        # Training uses the jnp oracle paths: AD must flow.
        logits, _, _ = forward_full(params, tokens, valid, cfg, geo, False)
        lp, ent = response_logp_ent(logits, tokens, valid, 1.0, cfg, geo, False)
        clip_low, clip_high = hp[1], hp[2]
        kl_coef, ent_coef = hp[3], hp[4]
        agg_mode = hp[5]

        log_ratio = lp - old_logp
        ratio = jnp.exp(jnp.clip(log_ratio, -20.0, 20.0))
        s1 = ratio * adv
        s2 = jnp.clip(ratio, 1.0 - clip_low, 1.0 + clip_high) * adv
        pg_tok = -jnp.minimum(s1, s2)
        # k3 KL estimator to the reference policy (GRPO regularizer).
        lr_ref = ref_logp - lp
        kl_tok = jnp.exp(jnp.clip(lr_ref, -20.0, 20.0)) - lr_ref - 1.0

        m = resp_mask
        ntok = jnp.maximum(m.sum(), 1.0)
        nrow = jnp.maximum((m.sum(axis=1) > 0).astype(jnp.float32).sum(), 1.0)
        rowlen = jnp.maximum(m.sum(axis=1), 1.0)

        def seq_mean(x):
            return (((x * m).sum(axis=1) / rowlen).sum()) / nrow

        def tok_mean(x):
            return (x * m).sum() / ntok

        def agg(x):
            return jnp.where(agg_mode > 0.5, tok_mean(x), seq_mean(x))

        pg = agg(pg_tok)
        kl = agg(kl_tok)
        entropy = agg(ent)
        loss = pg + kl_coef * kl - ent_coef * entropy
        clipped = (jnp.abs(ratio - jnp.clip(ratio, 1.0 - clip_low, 1.0 + clip_high)) > 1e-8)
        clip_frac = tok_mean(clipped.astype(jnp.float32))
        ratio_mean = tok_mean(ratio)
        return loss, (pg, kl, entropy, clip_frac, ratio_mean, ntok)

    def adamw(pflat, m, v, step, grads, lr, wd, max_gn):
        gn = jnp.sqrt(jnp.sum(grads * grads) + 1e-12)
        scale = jnp.minimum(1.0, max_gn / gn)
        grads = grads * scale
        b1, b2 = 0.9, 0.999
        step1 = step + 1.0
        m1 = b1 * m + (1 - b1) * grads
        v1 = b2 * v + (1 - b2) * grads * grads
        mhat = m1 / (1 - b1 ** step1)
        vhat = v1 / (1 - b2 ** step1)
        upd = mhat / (jnp.sqrt(vhat) + 1e-8) + wd * pflat
        return pflat - lr * upd, m1, v1, step1, gn

    def train_policy(blob, tokens, valid, resp_mask, adv, old_logp, ref_logp, hp):
        pflat, m, v, step, _ = split_blob(blob, cfg, geo, False)
        (loss, aux), grads = jax.value_and_grad(policy_loss, has_aux=True)(
            pflat, tokens, valid, resp_mask, adv, old_logp, ref_logp, hp
        )
        pg, kl, entropy, clip_frac, ratio_mean, ntok = aux
        p1, m1, v1, s1, gn = adamw(pflat, m, v, step, grads, hp[0], hp[6], hp[7])
        metrics = jnp.zeros((C.NUM_METRICS,), jnp.float32)
        metrics = metrics.at[0].set(loss).at[1].set(pg).at[2].set(kl)
        metrics = metrics.at[3].set(entropy).at[4].set(clip_frac).at[5].set(gn)
        metrics = metrics.at[6].set(ratio_mean).at[7].set(ntok)
        return join_blob(p1, m1, v1, s1, metrics)

    def sft_loss(pflat, tokens, valid, loss_mask, temp_unused=None):
        params = params_from_flat(pflat, cfg, geo, False)
        logits, _, _ = forward_full(params, tokens, valid, cfg, geo, False)
        # logits at slot t-1 predict token at slot t; loss_mask is aligned
        # to target slots [1, T).
        pred = logits[:, :-1, :]
        tgt = tokens[:, 1:]
        lp_all = jax.nn.log_softmax(pred, axis=-1)
        lp = jnp.take_along_axis(lp_all, tgt[..., None].astype(jnp.int32), axis=-1)[..., 0]
        m = loss_mask[:, 1:]
        ntok = jnp.maximum(m.sum(), 1.0)
        loss = -(lp * m).sum() / ntok
        acc = ((pred.argmax(-1) == tgt).astype(jnp.float32) * m).sum() / ntok
        return loss, acc

    def train_sft(blob, tokens, valid, loss_mask, hp):
        pflat, m, v, step, _ = split_blob(blob, cfg, geo, False)
        (loss, acc), grads = jax.value_and_grad(sft_loss, has_aux=True)(
            pflat, tokens, valid, loss_mask
        )
        p1, m1, v1, s1, gn = adamw(pflat, m, v, step, grads, hp[0], hp[6], hp[7])
        metrics = jnp.zeros((C.NUM_METRICS,), jnp.float32)
        metrics = metrics.at[0].set(loss).at[3].set(acc).at[5].set(gn)
        return join_blob(p1, m1, v1, s1, metrics)

    # -- read_gen: extract the sampling probs + aux lane from the gen blob ---
    # (CopyRawToHost is unimplemented on this CPU PJRT plugin, so reading a
    # sub-range of a device buffer requires a full literal copy; this trivial
    # executable keeps the per-decode-step host copy at B*V + B floats
    # instead of the whole KV cache. The aux tail carries verify_seat's
    # accepted-prefix lengths, so the pipeline learns acceptance results
    # from the same read it already performs per step.)
    def read_gen(gen_blob):
        gs = unpack_gen(gen_blob)
        return jnp.concatenate([gs["probs"].reshape(-1), gs["aux"].reshape(-1)])

    # -- sample: device-resident per-task sampling (ARCHITECTURE.md §12) -----
    def sample(gen_blob, ctrl, nonce, top_p):
        """Draw one token per armed row from the gen blob's probs, replaying
        the host's per-task RNG streams (§6) device-side. `ctrl` carries per
        row (task id, draws-so-far, mode): mode 0 skips the row, mode 1
        samples unconditionally (decode survivors and refill seats), mode 2
        samples iff the row's `live` lane is set (verify_seat seats whose
        termination only the device knows this round). Writes the token id
        into the `tok` lane (-1 for unarmed rows) and its raw probability
        into `ptok`; everything else passes through untouched."""
        gs = unpack_gen(gen_blob)
        ids, draws, mode = ctrl[:, 0], ctrl[:, 1], ctrl[:, 2]
        armed = jnp.logical_or(
            mode == 1, jnp.logical_and(mode == 2, gs["live"] > 0.5)
        )
        u = rng_k.task_uniform(nonce[0], nonce[1], ids, draws, g)
        tok, ptok = rng_k.device_sample(gs["probs"], u, top_p[0])
        tok_lane = jnp.where(armed, tok.astype(jnp.float32), -1.0)
        ptok_lane = jnp.where(armed, ptok, 0.0)
        return pack_gen(gs["cache_k"], gs["cache_v"], gs["valid"], gs["probs"],
                        gs["aux"], gs["live"], tok_lane, ptok_lane)

    # -- read_step: the fused O(B) end-of-step readback (§12) ----------------
    # (replaces read_gen's [B*V] probs payload on the pipeline hot path:
    # after `sample` the host only needs each row's token, its probability,
    # and verify_seat's acceptance offsets)
    def read_step(gen_blob):
        gs = unpack_gen(gen_blob)
        return jnp.concatenate(
            [gs["tok"].reshape(-1), gs["ptok"].reshape(-1), gs["aux"].reshape(-1)]
        )

    # -- read_metrics: extract [step | metrics] from a train blob ------------
    # (same rationale as read_gen: avoids a full blob copy per train step
    # just to read 17 floats of diagnostics)
    def read_metrics(blob):
        return blob[blob.shape[0] - 1 - C.NUM_METRICS :]

    entries = {
        "prefill": prefill,
        "decode": decode,
        "refill": refill,
        "read_gen": read_gen,
        "sample": sample,
        "read_step": read_step,
        "read_metrics": read_metrics,
        "score": score,
        "verify": verify,
        "verify_seat": verify_seat,
        "train_policy": train_policy,
        "train_sft": train_sft,
    }

    # ---- critic entries (PPO) ----------------------------------------------
    if critic_cfg is not None:
        ccfg = critic_cfg
        np_val = C.n_params(ccfg, geo, True)

        def value_params(blob):
            return params_from_flat(blob[:np_val], ccfg, geo, True)

        def value_fwd(blob, tokens, valid):
            params = value_params(blob)
            logits, _, _ = forward_full(params, tokens, valid, ccfg, geo, False, True)
            vals = logits[..., 0]  # [B,T]
            # V(s_j) = value read at slot P-1+j (state before response token j),
            # plus the terminal slot T-1: [B, G+1].
            return vals[:, p - 1 : t].reshape(-1)

        def value_loss(pflat, tokens, valid, resp_mask, targets):
            params = params_from_flat(pflat, ccfg, geo, True)
            logits, _, _ = forward_full(params, tokens, valid, ccfg, geo, False, True)
            vals = logits[:, p - 1 : t - 1, 0]  # [B,G]
            m = resp_mask
            ntok = jnp.maximum(m.sum(), 1.0)
            loss = (((vals - targets) ** 2) * m).sum() / ntok
            return loss, vals.mean()

        def train_value(blob, tokens, valid, resp_mask, targets, hp):
            pflat, m, v, step, _ = split_blob(blob, ccfg, geo, True)
            (loss, vmean), grads = jax.value_and_grad(value_loss, has_aux=True)(
                pflat, tokens, valid, resp_mask, targets
            )
            p1, m1, v1, s1, gn = adamw(pflat, m, v, step, grads, hp[0], hp[6], hp[7])
            metrics = jnp.zeros((C.NUM_METRICS,), jnp.float32)
            metrics = metrics.at[0].set(loss).at[5].set(gn).at[6].set(vmean)
            return join_blob(p1, m1, v1, s1, metrics)

        entries["value_fwd"] = value_fwd
        entries["train_value"] = train_value

    return entries
