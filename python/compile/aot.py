"""AOT pipeline: lower every L2 entry point to HLO text + write the manifest.

Usage (from ``python/``)::

    python -m compile.aot --out-dir ../artifacts [--models nano,tiny,small,base]
                          [--batch 32] [--prompt-len 16] [--total-len 64]
                          [--no-pallas]

For each model bundle this emits::

    artifacts/<model>_b<batch>/<entry>.hlo.txt   one per entry point
    artifacts/<model>_b<batch>/init.npy          initial policy blob (f32, 1-D)
    artifacts/manifest.json                      machine-readable signatures

Interchange format is **HLO text**, not serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids that xla_extension
0.5.1 (the version the published ``xla`` rust crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import config as C
from . import model as M

F32, I32 = "f32", "i32"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def arg_spec(dtype: str, shape):
    jt = jnp.float32 if dtype == F32 else jnp.int32
    return jax.ShapeDtypeStruct(tuple(shape), jt)


def entry_signatures(cfg: C.ModelConfig, geo: C.SeqGeometry, batch: int,
                     value_head: bool) -> Dict[str, List[Dict[str, Any]]]:
    """Input signature (ordered) for every entry point of one bundle."""
    b, t, g = batch, geo.total_len, geo.gen_len
    s = C.blob_size(cfg, geo, value_head)
    sg = C.flat_size(C.gen_blob_spec(cfg, geo, b))

    def a(name, dtype, *shape):
        return {"name": name, "dtype": dtype, "shape": list(shape)}

    common_tv = [a("tokens", I32, b, t), a("valid", F32, b, t)]
    sigs = {
        "prefill": [a("blob", F32, s)] + common_tv + [a("last", I32, b), a("temp", F32, 1)],
        # decode carries no [B, T] valid arg: the mask lives in `gen` and is
        # extended device-side from `slot` (see config.gen_blob_spec).
        "decode": [a("blob", F32, s), a("gen", F32, sg), a("token", I32, b),
                   a("slot", I32, b), a("lpos", I32, b), a("temp", F32, 1)],
        # masked per-row re-prefill for continuous-batching slot refills
        "refill": [a("blob", F32, s), a("gen", F32, sg)] + common_tv + [
            a("rowmask", F32, b), a("last", I32, b), a("temp", F32, 1)],
        "read_gen": [a("gen", F32, sg)],
        # device-resident sampling (ARCHITECTURE.md §12): ctrl rows are
        # (task id, draws consumed so far, arm mode), nonce is the u64 step
        # nonce bit-split into (hi, lo) i32 words
        "sample": [a("gen", F32, sg), a("ctrl", I32, b, 3), a("nonce", I32, 2),
                   a("top_p", F32, 1)],
        # the fused O(B) readback that replaces read_gen on the hot path
        "read_step": [a("gen", F32, sg)],
        "read_metrics": [a("blob", F32, s)],
        "score": [a("blob", F32, s)] + common_tv + [a("temp", F32, 1)],
        "verify": [a("blob", F32, s)] + common_tv + [
            a("logp_prev", F32, b, g), a("uniforms", F32, b, g),
            a("draft_valid", F32, b, g), a("loglen", F32, 1), a("temp", F32, 1)],
        # verification folded into the slot pool: scores drafts AND seats the
        # accepted prefixes (KV/valid/probs) into `gen` for masked rows; the
        # accepted length lands in the gen blob's aux lane (read via read_gen)
        "verify_seat": [a("blob", F32, s), a("gen", F32, sg)] + common_tv + [
            a("logp_prev", F32, b, g), a("uniforms", F32, b, g),
            a("draft_valid", F32, b, g), a("rowmask", F32, b),
            a("loglen", F32, 1), a("temp", F32, 1)],
        "train_policy": [a("blob", F32, s)] + common_tv + [
            a("resp_mask", F32, b, g), a("adv", F32, b, g),
            a("old_logp", F32, b, g), a("ref_logp", F32, b, g), a("hp", F32, 8)],
        "train_sft": [a("blob", F32, s)] + common_tv + [
            a("loss_mask", F32, b, t), a("hp", F32, 8)],
    }
    if value_head:
        sigs = {
            "value_fwd": [a("blob", F32, s)] + common_tv,
            "train_value": [a("blob", F32, s)] + common_tv + [
                a("resp_mask", F32, b, g), a("targets", F32, b, g), a("hp", F32, 8)],
            "read_metrics": [a("blob", F32, s)],
        }
    return sigs


def output_fields(name: str, cfg, geo, batch: int, value_head: bool):
    """Ordered (field, offset, shape) description of each entry's flat output."""
    b, t, g, v = batch, geo.total_len, geo.gen_len, cfg.vocab
    n = C.n_params(cfg, geo, value_head)
    l, d = cfg.n_layers, cfg.d_model
    if name in ("prefill", "decode", "refill", "verify_seat", "sample"):
        base = 2 * l * b * t * d
        return [
            {"name": "cache_k", "offset": 0, "shape": [l, b, t, d]},
            {"name": "cache_v", "offset": l * b * t * d, "shape": [l, b, t, d]},
            {"name": "valid", "offset": base, "shape": [b, t]},
            {"name": "probs", "offset": base + b * t, "shape": [b, v]},
            {"name": "aux", "offset": base + b * t + b * v, "shape": [b]},
            {"name": "live", "offset": base + b * t + b * v + b, "shape": [b]},
            {"name": "tok", "offset": base + b * t + b * v + 2 * b, "shape": [b]},
            {"name": "ptok", "offset": base + b * t + b * v + 3 * b, "shape": [b]},
        ]
    if name == "score":
        return [
            {"name": "logp", "offset": 0, "shape": [b, g]},
            {"name": "entropy", "offset": b * g, "shape": [b, g]},
        ]
    if name == "verify":
        return [
            {"name": "reject_off", "offset": 0, "shape": [b]},
            {"name": "logp", "offset": b, "shape": [b, g]},
            {"name": "entropy", "offset": b + b * g, "shape": [b, g]},
        ]
    if name in ("train_policy", "train_sft", "train_value"):
        return [
            {"name": "params", "offset": 0, "shape": [n]},
            {"name": "adam_m", "offset": n, "shape": [n]},
            {"name": "adam_v", "offset": 2 * n, "shape": [n]},
            {"name": "step", "offset": 3 * n, "shape": [1]},
            {"name": "metrics", "offset": 3 * n + 1, "shape": [C.NUM_METRICS]},
        ]
    if name == "read_gen":
        return [
            {"name": "probs", "offset": 0, "shape": [b, v]},
            {"name": "aux", "offset": b * v, "shape": [b]},
        ]
    if name == "read_step":
        return [
            {"name": "tok", "offset": 0, "shape": [b]},
            {"name": "ptok", "offset": b, "shape": [b]},
            {"name": "aux", "offset": 2 * b, "shape": [b]},
        ]
    if name == "read_metrics":
        return [
            {"name": "step", "offset": 0, "shape": [1]},
            {"name": "metrics", "offset": 1, "shape": [C.NUM_METRICS]},
        ]
    if name == "value_fwd":
        return [{"name": "values", "offset": 0, "shape": [b, g + 1]}]
    raise ValueError(name)


def lower_bundle(model_name: str, batch: int, geo: C.SeqGeometry, out_dir: str,
                 use_pallas: bool, seed: int, pallas_attention: bool = False) -> Dict[str, Any]:
    cfg = C.PRESETS[model_name]
    value_head = model_name == "critic"
    bundle = f"{model_name}_b{batch}"
    bdir = os.path.join(out_dir, bundle)
    os.makedirs(bdir, exist_ok=True)

    entries = M.make_entries(
        cfg, geo, batch, use_pallas=use_pallas,
        critic_cfg=cfg if value_head else None,
        pallas_attention=pallas_attention,
    )
    sigs = entry_signatures(cfg, geo, batch, value_head)

    info: Dict[str, Any] = {
        "model": {
            "name": cfg.name, "n_layers": cfg.n_layers, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "vocab": cfg.vocab,
        },
        "batch": batch,
        "value_head": value_head,
        "n_params": C.n_params(cfg, geo, value_head),
        "blob_size": C.blob_size(cfg, geo, value_head),
        "gen_blob_size": C.flat_size(C.gen_blob_spec(cfg, geo, batch)),
        "init_blob": f"{bundle}/init.npy",
        "entries": {},
    }

    for name, sig in sigs.items():
        fn = entries[name]
        specs = [arg_spec(a["dtype"], a["shape"]) for a in sig]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{bundle}/{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_size = sum(
            int(np.prod(fld["shape"])) for fld in output_fields(name, cfg, geo, batch, value_head)
        )
        info["entries"][name] = {
            "file": fname,
            "inputs": sig,
            "output_size": out_size,
            "output_fields": output_fields(name, cfg, geo, batch, value_head),
        }
        print(f"  lowered {bundle}/{name}: {len(text)} chars")

    blob = M.init_blob(seed, cfg, geo, value_head)
    np.save(os.path.join(out_dir, f"{bundle}/init.npy"), blob)
    return info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="nano,tiny,small,critic")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--total-len", type=int, default=64)
    ap.add_argument("--no-pallas", action="store_true")
    ap.add_argument("--pallas-attention", action="store_true",
                    help="use the Pallas attention kernel in the scoring paths "
                         "(correct but ~6x slower under interpret=True on CPU; "
                         "the acceptance/logprob kernels are always Pallas unless "
                         "--no-pallas)")
    ap.add_argument("--seed", type=int, default=1234)
    args = ap.parse_args()

    geo = C.SeqGeometry(prompt_len=args.prompt_len, total_len=args.total_len)
    os.makedirs(args.out_dir, exist_ok=True)

    manifest: Dict[str, Any] = {
        "charset": C.CHARSET,
        "specials": C.SPECIALS,
        "vocab": C.VOCAB_SIZE,
        "geometry": {"prompt_len": geo.prompt_len, "total_len": geo.total_len},
        "hp_names": ["lr", "clip_low", "clip_high", "kl_coef", "ent_coef",
                      "loss_agg_mode", "weight_decay", "max_grad_norm"],
        "metric_slots": C.METRIC_SLOTS,
        "use_pallas": not args.no_pallas,
        "pallas_attention": args.pallas_attention,
        "bundles": {},
    }
    for mname in args.models.split(","):
        mname = mname.strip()
        print(f"lowering bundle {mname}_b{args.batch} ...")
        manifest["bundles"][f"{mname}_b{args.batch}"] = lower_bundle(
            mname, args.batch, geo, args.out_dir, not args.no_pallas, args.seed,
            pallas_attention=args.pallas_attention,
        )

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
