"""Model / geometry configuration shared by the AOT pipeline.

The single source of truth for: the char-level vocabulary, the sequence
geometry (prompt region / generation region), the transformer presets that
substitute for the paper's Qwen3/LLaMA backbones, and the flat parameter
layout ("blob") that the rust runtime addresses by byte offset.

Everything here is serialized into ``artifacts/manifest.json`` so the rust
L3 never hardcodes a shape.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

# --- vocabulary ------------------------------------------------------------
# Char-level tokenizer. Order matters: ids are positions in this string,
# offset by the three specials. Must match rust/src/tokenizer.
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
SPECIALS = ["<pad>", "<bos>", "<eos>"]
CHARSET = "0123456789+-*/%()=<> abcdefghijklmnopqrstuvwxyz?"
VOCAB_SIZE = len(SPECIALS) + len(CHARSET)  # 51


# --- sequence geometry -------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SeqGeometry:
    """Static sequence layout (canonical slots, see DESIGN.md).

    Slots ``[0, prompt_len)`` hold the right-aligned (left-padded) prompt;
    slots ``[prompt_len, total_len)`` hold the response. All entry points
    are lowered for these static shapes.
    """

    prompt_len: int = 16
    total_len: int = 64

    @property
    def gen_len(self) -> int:
        return self.total_len - self.prompt_len


# --- model presets -----------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer hyperparameters (paper-backbone substitute)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int = VOCAB_SIZE

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Substitutes for the paper's backbones (Table 1 rows + Table 5):
#   tiny  ~ Qwen3-1.7B-Base   small ~ Qwen3-8B-Base
#   base  ~ Qwen3-14B-Base    nano  ~ LLaMA-3.2-1B-Instruct (different family:
#                                     narrower ff ratio + fewer heads)
PRESETS: Dict[str, ModelConfig] = {
    "nano": ModelConfig("nano", n_layers=2, d_model=48, n_heads=2, d_ff=96),
    "tiny": ModelConfig("tiny", n_layers=2, d_model=64, n_heads=2, d_ff=256),
    "small": ModelConfig("small", n_layers=4, d_model=128, n_heads=4, d_ff=512),
    "base": ModelConfig("base", n_layers=6, d_model=192, n_heads=6, d_ff=768),
    # critic trunk for PPO (value head instead of lm head)
    "critic": ModelConfig("critic", n_layers=2, d_model=64, n_heads=2, d_ff=256),
}


# --- flat parameter layout ---------------------------------------------------
def param_layout(cfg: ModelConfig, geo: SeqGeometry, value_head: bool = False) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the parameter section of a blob.

    The rust runtime and the python init/training graphs all use this order;
    offsets are cumulative products of the shapes.
    """
    out_dim = 1 if value_head else cfg.vocab
    layout: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (geo.total_len, cfg.d_model)),
    ]
    for l in range(cfg.n_layers):
        layout += [
            (f"l{l}.ln1", (cfg.d_model,)),
            (f"l{l}.wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (f"l{l}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{l}.ln2", (cfg.d_model,)),
            (f"l{l}.w1", (cfg.d_model, cfg.d_ff)),
            (f"l{l}.w2", (cfg.d_ff, cfg.d_model)),
        ]
    layout += [
        ("ln_f", (cfg.d_model,)),
        ("head", (cfg.d_model, out_dim)),
    ]
    return layout


def n_params(cfg: ModelConfig, geo: SeqGeometry, value_head: bool = False) -> int:
    total = 0
    for _, shape in param_layout(cfg, geo, value_head):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


# Blob layout: [params | adam_m | adam_v | step(1) | metrics(NUM_METRICS)]
NUM_METRICS = 16
# Metric slot names for the train blobs (rust reads by index):
METRIC_SLOTS = [
    "loss", "pg_loss", "kl", "entropy", "clip_frac", "grad_norm",
    "ratio_mean", "token_count", "aux0", "aux1", "aux2", "aux3",
    "aux4", "aux5", "aux6", "aux7",
]


def blob_size(cfg: ModelConfig, geo: SeqGeometry, value_head: bool = False) -> int:
    return 3 * n_params(cfg, geo, value_head) + 1 + NUM_METRICS


# Gen blob layout (per batch):
#   [cache_k | cache_v | valid | probs | aux | live | tok | ptok].
# The [B, T] valid mask is part of the device-resident generation state:
# prefill seeds it, decode extends it in place via a one-hot slot write,
# refill replaces it for masked rows. The host never re-uploads it per
# decode step (see rust/src/rollout/sched.rs for the full contract).
#
# `aux` is a per-row f32 side channel for entries that must report a small
# scalar alongside the new generation state: ``verify_seat`` writes each
# seated row's accepted-prefix length there (prefill zeroes it; decode and
# refill pass it through). ``read_gen`` returns [probs | aux], so the host
# learns acceptance results from the read it already performs per step.
#
# `live`/`tok`/`ptok` are the device-resident sampling lanes
# (ARCHITECTURE.md §12): ``verify_seat`` raises `live` to 1.0 for seated
# rows whose accepted prefix is not yet terminal, the ``sample`` entry draws
# one token per armed row (writing the token id into `tok` and its raw
# probability into `ptok` — the host applies ``ln`` so logps stay
# bit-identical to the host sampler), and ``read_step`` returns just
# [tok | ptok | aux] — the fused O(B) readback that replaces ``read_gen``'s
# O(B*V) probs payload on the pipeline hot path.
def gen_blob_spec(cfg: ModelConfig, geo: SeqGeometry, batch: int):
    """Returns ordered (name, shape) fields of the generation-state blob."""
    l, b, t, d = cfg.n_layers, batch, geo.total_len, cfg.d_model
    return [
        ("cache_k", (l, b, t, d)),
        ("cache_v", (l, b, t, d)),
        ("valid", (b, t)),
        ("probs", (b, cfg.vocab)),
        ("aux", (b,)),
        ("live", (b,)),
        ("tok", (b,)),
        ("ptok", (b,)),
    ]


def flat_size(fields) -> int:
    total = 0
    for _, shape in fields:
        n = 1
        for dim in shape:
            n *= dim
        total += n
    return total
