"""Lenient speculative-acceptance scan as a Pallas kernel.

Implements Algorithm 1 (lines 1-8) of the paper, batched: given the
log-probs of the cached draft tokens under the current policy
(``logp_curr``, produced by the scoring forward) and the log-probs recorded
when the draft was sampled (``logp_prev``), accept token ``j`` iff::

    u_j <= min(1, l * p_curr / p_prev)

and report the first rejected offset per row. Fusing this into the same
HLO module as the scoring forward means the acceptance decision costs one
extra VPU pass over ``[B, G]`` — the ``[B, T, V]`` logits never leave the
device and nothing is re-synchronized with the host between scoring and
acceptance (the paper's "single call to the rollout engine").

Pure elementwise + row-reduction work: tiles of ``(block_b, G)`` rows in
VMEM, no MXU involvement. Lowered with ``interpret=True`` for CPU PJRT.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _accept_kernel(loglen_ref, lc_ref, lp_ref, u_ref, dv_ref, rej_ref, la_ref, *, g):
    """One block_b-rows grid cell.

    loglen_ref: f32[1]            log lenience (scalar, broadcast)
    lc_ref:     f32[block_b, G]   logp under pi_curr
    lp_ref:     f32[block_b, G]   logp under pi_prev (recorded at sampling)
    u_ref:      f32[block_b, G]   U(0,1) from the coordinator's RNG
    dv_ref:     f32[block_b, G]   1.0 where the draft has a token
    rej_ref:    i32[block_b]      OUT first rejected offset (== draft len if none)
    la_ref:     f32[block_b, G]   OUT per-token log acceptance prob (diagnostics)
    """
    log_len = loglen_ref[0]
    lc = lc_ref[...]
    lp = lp_ref[...]
    u = u_ref[...]
    dv = dv_ref[...]

    log_alpha = jnp.minimum(0.0, log_len + lc - lp)
    rejected = (u > jnp.exp(log_alpha)) & (dv > 0.5)

    iota = jax.lax.broadcasted_iota(jnp.int32, rejected.shape, 1)
    reject_idx = jnp.where(rejected, iota, g).min(axis=1)
    draft_len = dv.sum(axis=1).astype(jnp.int32)

    rej_ref[...] = jnp.minimum(reject_idx, draft_len)
    la_ref[...] = log_alpha


def spec_accept(
    logp_curr, logp_prev, uniforms, draft_valid, log_lenience, *, block_b=None, interpret=True
):
    """Batched acceptance scan. Shapes as :func:`ref.ref_spec_accept`.

    ``log_lenience`` is a scalar (or ()-shaped array); +inf forces full
    reuse, -inf forces rejection at offset 0 (vanilla RLVR).

    Returns ``(reject_off i32[B], log_alpha f32[B, G])``.
    """
    b, g = logp_curr.shape
    if block_b is None:
        from .attention import _pick_block

        block_b = _pick_block(b, 8)
    assert b % block_b == 0, (b, block_b)
    loglen = jnp.asarray(log_lenience, dtype=jnp.float32).reshape(1)

    grid = (b // block_b,)
    rej, la = pl.pallas_call(
        functools.partial(_accept_kernel, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),                 # log lenience
            pl.BlockSpec((block_b, g), lambda i: (i, 0)),       # logp_curr
            pl.BlockSpec((block_b, g), lambda i: (i, 0)),       # logp_prev
            pl.BlockSpec((block_b, g), lambda i: (i, 0)),       # uniforms
            pl.BlockSpec((block_b, g), lambda i: (i, 0)),       # draft_valid
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, g), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b, g), jnp.float32),
        ],
        interpret=interpret,
    )(loglen, logp_curr, logp_prev, uniforms, draft_valid)
    return rej, la
