"""Flash-style tiled causal attention as a Pallas kernel.

This is the hot compute of the SPEC-RL *verification* pass: scoring a whole
``[B, T]`` batch of cached drafts under the current policy is one
teacher-forced forward whose cost is dominated by causal attention. The
paper runs this inside vLLM on H100s; here the same computation is
re-thought for a TPU memory hierarchy (see DESIGN.md §Hardware-Adaptation):

- BlockSpec stages ``(block_q, Dh)`` query tiles and the row's K/V into
  VMEM; the inner loop walks K in ``block_k`` tiles, so HBM->VMEM traffic
  pipelines across grid steps the way CUDA kernels overlap gmem->smem.
- Online softmax (running max ``m``, running denominator ``s``) keeps the
  accumulator in f32 VMEM scratch; nothing of size ``T x T`` is ever
  materialized.
- Causal structure is exploited at *block* granularity: k-tiles strictly
  above the diagonal are skipped by index arithmetic (no per-lane
  divergence, which the MXU/VPU could not hide anyway).

Lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel runs as traced jnp; the *structure* (tiling,
VMEM budget) is what carries to real TPUs and is what DESIGN.md §Perf
estimates from.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(valid_ref, q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, t, scale):
    """One (batch*head, q-tile) grid cell.

    valid_ref: f32[T]        per-row token-valid flags (left padding)
    q_ref:     f32[block_q, Dh]
    k_ref:     f32[T, Dh]    whole row of keys (small T), walked in tiles
    v_ref:     f32[T, Dh]
    o_ref:     f32[block_q, Dh]
    """
    iq = pl.program_id(1)
    q = q_ref[...] * scale
    dh = q.shape[-1]

    q_idx = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    acc = jnp.zeros((block_q, dh), dtype=jnp.float32)
    m_i = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    s_i = jnp.zeros((block_q,), dtype=jnp.float32)

    # Only k-tiles at or below the diagonal contribute: tile jk is live iff
    # jk*block_k <= iq*block_q + block_q - 1.
    num_live = jnp.minimum((iq + 1) * block_q + block_k - 1, t) // block_k

    def body(jk, carry):
        acc, m_i, s_i = carry
        k_tile = k_ref[pl.ds(jk * block_k, block_k), :]
        v_tile = v_ref[pl.ds(jk * block_k, block_k), :]
        vmask = valid_ref[pl.ds(jk * block_k, block_k)]

        scores = q @ k_tile.T  # [block_q, block_k]
        k_idx = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = (k_idx <= q_idx) & (vmask[None, :] > 0.5)
        scores = jnp.where(mask, scores, NEG_INF)

        m_new = jnp.maximum(m_i, scores.max(axis=1))
        # Rescale previous accumulator to the new max.
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(scores - m_new[:, None])
        s_new = s_i * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v_tile
        return acc_new, m_new, s_new

    acc, m_i, s_i = jax.lax.fori_loop(0, num_live, body, (acc, m_i, s_i))
    # Rows that saw no valid key (fully padded prefix) would divide by zero;
    # they are never read downstream, emit zeros.
    denom = jnp.where(s_i > 0.0, s_i, 1.0)
    o_ref[...] = (acc / denom[:, None]).astype(o_ref.dtype)


def _pick_block(t, want):
    """Largest power-of-two divisor of t not exceeding `want`."""
    b = 1
    while b * 2 <= want and t % (b * 2) == 0:
        b *= 2
    return b


def attention(q, k, v, valid, scale, *, block_q=None, block_k=None, interpret=True):
    """Tiled causal attention. Shapes as :func:`ref.ref_attention`.

    Grid: ``(B*H, T/block_q)``; each cell streams K/V in ``block_k`` tiles.
    VMEM per cell: ``(block_q + 2*T)*Dh*4`` bytes plus ``block_q*block_k``
    score tile — for the `base` config (T=64, Dh=32) about 18 KiB, far
    under the ~16 MiB/core VMEM budget, leaving room for the pipeline's
    double buffers.
    """
    b, h, t, dh = q.shape
    block_q = block_q or _pick_block(t, 16)
    block_k = block_k or _pick_block(t, 16)
    assert t % block_q == 0 and t % block_k == 0, (t, block_q, block_k)
    bh = b * h

    qf = q.reshape(bh, t, dh)
    kf = k.reshape(bh, t, dh)
    vf = v.reshape(bh, t, dh)
    validf = jnp.repeat(valid, h, axis=0)  # [B*H, T]

    grid = (bh, t // block_q)
    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, block_q=block_q, block_k=block_k, t=t, scale=scale
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, t), lambda i, j: (i, 0)),         # valid
            pl.BlockSpec((None, block_q, dh), lambda i, j: (i, j, 0)),  # q
            pl.BlockSpec((None, t, dh), lambda i, j: (i, 0, 0)),  # k
            pl.BlockSpec((None, t, dh), lambda i, j: (i, 0, 0)),  # v
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, dh), q.dtype),
        interpret=interpret,
    )(validf, qf, kf, vf)
    return out.reshape(b, h, t, dh)
