"""L1 Pallas kernels for the SPEC-RL stack.

Three kernels cover the rollout-verification hot path:

- :mod:`attention` -- flash-style tiled causal attention used by the
  teacher-forced scoring forward (the verification pass over cached drafts).
- :mod:`spec_accept` -- the lenient speculative acceptance scan
  (Algorithm 1, lines 1-8 of the paper), batched over rows.
- :mod:`logprob` -- fused log-softmax-gather + entropy so the [N, V]
  logits are consumed in one pass.

All kernels lower with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); :mod:`ref` holds the pure-jnp oracles that pytest checks
them against.
"""
