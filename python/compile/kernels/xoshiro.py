"""Device-side replay of the coordinator's RNG + top-p sampler.

The rust coordinator derives one xoshiro256** stream per task
(``task_rng(nonce, id)``, ARCHITECTURE.md §6) and consumes exactly one
``f32`` per sampled token. The ``sample`` entry replays those streams on
the device so the per-step readback can shrink from O(B*V) probs to O(B)
tokens (§12): for each row it re-seeds from ``(nonce, id)``, skips the
``draws`` values the host already consumed, draws the next one, and runs
the same nucleus inverse-CDF the host's ``TopPSampler`` runs.

Bit-exactness contract: every integer op here is the u64 pipeline from
``rust/src/util/rng.rs`` (SplitMix64 seeding, xoshiro256** core) emulated
as (hi, lo) pairs of uint32 — jax's default x64-disabled mode has no u64
— and every float op is a plain IEEE f32 add/sub/mul/compare evaluated in
the same sequential order as the rust sampler (``lax.scan``, never
``jnp.sum``, which may reassociate). No transcendental is evaluated on
device: the entry reports the sampled token's raw probability and the
host applies ``ln`` itself, so result logps are bit-identical to the
host-sampling path by construction.

This is deliberately plain jnp rather than a Pallas kernel: the work is
O(B * G) scalar integer ops plus an O(B * V) scan — memory-trivial, no
tiling to exploit — the same split DESIGN.md makes for the decode path.

A pure-python reference (``ref_*``) mirrors rust semantics exactly (u64
masks + ``np.float32`` arithmetic) and pins the device stream in
``python/tests/test_aot.py``.
"""

from __future__ import annotations

import jax
import numpy as np
from jax import lax
import jax.numpy as jnp

# splitmix64 / task-seed constants (rust/src/util/rng.rs)
GAMMA = 0x9E37_79B9_7F4A_7C15
SM_MUL1 = 0xBF58_476D_1CE4_E5B9
SM_MUL2 = 0x94D0_49BB_1331_11EB
MASK64 = (1 << 64) - 1

_U24_SCALE = np.float32(1.0 / (1 << 24))


# --------------------------------------------------------------------------
# u64 arithmetic over (hi, lo) uint32 pairs
# --------------------------------------------------------------------------
def _u32(x):
    return jnp.asarray(x, jnp.uint32)


def const64(value: int):
    """A python int as a broadcastable (hi, lo) uint32 pair."""
    return _u32((value >> 32) & 0xFFFF_FFFF), _u32(value & 0xFFFF_FFFF)


def xor64(a, b):
    return a[0] ^ b[0], a[1] ^ b[1]


def add64(a, b):
    lo = a[1] + b[1]
    carry = (lo < a[1]).astype(jnp.uint32)
    return a[0] + b[0] + carry, lo


def shl64(x, k: int):
    if k == 0:
        return x
    if k < 32:
        return (x[0] << k) | (x[1] >> (32 - k)), x[1] << k
    if k == 32:
        return x[1], jnp.zeros_like(x[1])
    return x[1] << (k - 32), jnp.zeros_like(x[1])


def shr64(x, k: int):
    if k == 0:
        return x
    if k < 32:
        return x[0] >> k, (x[1] >> k) | (x[0] << (32 - k))
    if k == 32:
        return jnp.zeros_like(x[0]), x[0]
    return jnp.zeros_like(x[0]), x[0] >> (k - 32)


def rotl64(x, k: int):
    a = shl64(x, k)
    b = shr64(x, 64 - k)
    return a[0] | b[0], a[1] | b[1]


def _mul32(a, b):
    """Full 64-bit product of two uint32 arrays, as (hi, lo) uint32."""
    m16 = _u32(0xFFFF)
    a0, a1 = a & m16, a >> 16
    b0, b1 = b & m16, b >> 16
    t = a0 * b0
    w0 = t & m16
    t = a1 * b0 + (t >> 16)
    w1 = t & m16
    w2 = t >> 16
    t = a0 * b1 + w1
    hi = a1 * b1 + w2 + (t >> 16)
    lo = (t << 16) | w0
    return hi, lo


def mul64(a, b):
    """Low 64 bits of the u64 product (rust ``wrapping_mul``)."""
    hi, lo = _mul32(a[1], b[1])
    cross = a[1] * b[0] + a[0] * b[1]
    return hi + cross, lo


# --------------------------------------------------------------------------
# splitmix64 seeding + xoshiro256** core (vectorized over rows)
# --------------------------------------------------------------------------
def _splitmix64(state):
    state = add64(state, const64(GAMMA))
    z = state
    z = mul64(xor64(z, shr64(z, 30)), const64(SM_MUL1))
    z = mul64(xor64(z, shr64(z, 27)), const64(SM_MUL2))
    return state, xor64(z, shr64(z, 31))


def xoshiro_init(seed):
    """Rng::new — four splitmix64 draws fill s[0..4]."""
    s = []
    for _ in range(4):
        seed, z = _splitmix64(seed)
        s.append(z)
    return s


def xoshiro_next(s):
    """One xoshiro256** step: returns (new_state, result)."""
    s0, s1, s2, s3 = s
    result = mul64(rotl64(mul64(s1, const64(5)), 7), const64(9))
    t = shl64(s1, 17)
    s2 = xor64(s2, s0)
    s3 = xor64(s3, s1)
    s1 = xor64(s1, s2)
    s0 = xor64(s0, s3)
    s2 = xor64(s2, t)
    s3 = rotl64(s3, 45)
    return [s0, s1, s2, s3], result


def task_uniform(nonce_hi, nonce_lo, ids, draws, max_draws: int):
    """Each row's next sampler uniform, replayed from its task stream.

    ``task_rng(nonce, id)`` seeds ``nonce ^ (id+1)*GAMMA``; the row has
    already consumed ``draws`` f32 values, so its next uniform is draw
    index ``draws``: step the generator ``max_draws + 1`` times and keep
    each row's value at its own index (draws <= max_draws always — the
    host arms at most one draw per generated token).

    nonce_hi/nonce_lo: i32 scalars (the u64 step nonce, bit-split);
    ids/draws: i32[B]. Returns f32[B] uniforms in [0, 1).
    """
    nonce = (
        jnp.broadcast_to(lax.bitcast_convert_type(nonce_hi, jnp.uint32), ids.shape),
        jnp.broadcast_to(lax.bitcast_convert_type(nonce_lo, jnp.uint32), ids.shape),
    )
    idp1 = (jnp.zeros_like(ids, jnp.uint32), (ids + 1).astype(jnp.uint32))
    seed = xor64(nonce, mul64(idp1, const64(GAMMA)))
    state = xoshiro_init(seed)
    draws = draws.astype(jnp.uint32)

    def body(k, carry):
        s, sel = carry
        s, result = xoshiro_next(s)
        # rust f32(): top 24 bits of the u64 result = hi >> 8
        bits24 = result[0] >> 8
        sel = jnp.where(draws == k, bits24, sel)
        return s, sel

    _, sel = lax.fori_loop(
        0, max_draws + 1, body, (state, jnp.zeros_like(ids, jnp.uint32))
    )
    return sel.astype(jnp.float32) * _U24_SCALE


# --------------------------------------------------------------------------
# the host TopPSampler's inverse CDF, sequential-f32-exact
# --------------------------------------------------------------------------
def _seq_sum(cols):
    """Left-to-right f32 accumulation over the leading axis of [V, B]."""

    def f(acc, p):
        return acc + p, None

    total, _ = lax.scan(f, jnp.zeros(cols.shape[1], jnp.float32), cols)
    return total


def _categorical(probs, u01):
    """top_p >= 1 branch: inverse CDF over the raw distribution."""
    b, v = probs.shape
    cols = probs.T  # [V, B]
    u0 = u01 * _seq_sum(cols)

    def f(carry, xs):
        u, chosen, found = carry
        i, p = xs
        u = u - p
        take = jnp.logical_and(jnp.logical_not(found), u <= 0.0)
        chosen = jnp.where(take, i, chosen)
        return (u, chosen, jnp.logical_or(found, take)), None

    init = (u0, jnp.full((b,), v - 1, jnp.int32), jnp.zeros((b,), bool))
    (_, chosen, _), _ = lax.scan(f, init, (jnp.arange(v, dtype=jnp.int32), cols))
    return chosen


def _nucleus(probs, u01, top_p):
    """top_p < 1 branch: sort desc (ties by index), cut at the mass
    budget, inverse CDF over the kept prefix, fallback last kept."""
    b, v = probs.shape
    # stable argsort of -p == prob-desc with index-asc tie-break, the
    # host sampler's exact comparator
    order = jnp.argsort(-probs, axis=-1, stable=True)  # [B, V]
    sp = jnp.take_along_axis(probs, order, axis=-1).T  # [V, B] sorted
    budget = top_p * _seq_sum(probs.T)

    # one pass finds the cut and the kept mass: `mass` accumulates in
    # sorted order and freezes once it crosses `budget`, which is both
    # the host's break condition and (same adds, same order) its
    # separately-summed kept_mass
    def f(carry, p):
        mass, found = carry
        kept = jnp.logical_not(found)
        mass = jnp.where(kept, mass + p, mass)
        found = jnp.logical_or(found, mass >= budget)
        return (mass, found), kept

    (kept_mass, _), kept = lax.scan(
        f, (jnp.zeros((b,), jnp.float32), jnp.zeros((b,), bool)), sp
    )
    kept = kept.T  # [B, V] rank-kept flags
    last_kept = jnp.maximum(
        jnp.sum(kept.astype(jnp.int32), axis=-1) - 1, 0
    )  # = cut - 1

    def g(carry, xs):
        u, chosen, found = carry
        r, p, k = xs
        u = jnp.where(k, u - p, u)
        take = jnp.logical_and(k, jnp.logical_and(jnp.logical_not(found), u <= 0.0))
        chosen = jnp.where(take, r, chosen)
        return (u, chosen, jnp.logical_or(found, take)), None

    init = (u01 * kept_mass, jnp.zeros((b,), jnp.int32), jnp.zeros((b,), bool))
    (_, chosen, found), _ = lax.scan(
        g, init, (jnp.arange(v, dtype=jnp.int32), sp, kept.T)
    )
    rank = jnp.where(found, chosen, last_kept)
    return jnp.take_along_axis(order, rank[:, None], axis=-1)[:, 0]


def device_sample(probs, u01, top_p):
    """Sample one token per row, bit-matching ``TopPSampler::sample``.

    probs: f32[B, V] (need not be normalized); u01: f32[B] uniforms;
    top_p: f32 scalar (shared across rows, like the host's SampleCfg).
    Returns (tok i32[B], ptok f32[B]) — ptok is the raw probability of
    the sampled token (the host takes the log).
    """
    tok = lax.cond(
        top_p >= np.float32(0.999_999),
        lambda: _categorical(probs, u01),
        lambda: _nucleus(probs, u01, top_p),
    )
    ptok = jnp.take_along_axis(probs, tok[:, None], axis=-1)[:, 0]
    return tok, ptok


# --------------------------------------------------------------------------
# pure-python reference (pins the device stream in test_aot.py)
# --------------------------------------------------------------------------
def ref_splitmix64(state: int):
    state = (state + GAMMA) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * SM_MUL1) & MASK64
    z = ((z ^ (z >> 27)) * SM_MUL2) & MASK64
    return state, z ^ (z >> 31)


def ref_xoshiro_init(seed: int):
    s = []
    for _ in range(4):
        seed, z = ref_splitmix64(seed)
        s.append(z)
    return s


def ref_xoshiro_next(s):
    def rotl(x, k):
        return ((x << k) | (x >> (64 - k))) & MASK64

    result = (rotl((s[1] * 5) & MASK64, 7) * 9) & MASK64
    t = (s[1] << 17) & MASK64
    s[2] ^= s[0]
    s[3] ^= s[1]
    s[1] ^= s[2]
    s[0] ^= s[3]
    s[2] ^= t
    s[3] = rotl(s[3], 45)
    return s, result


def ref_task_uniform(nonce: int, task_id: int, draws: int) -> np.float32:
    """rust ``task_rng(nonce, id)`` advanced ``draws`` f32s, next f32."""
    seed = nonce ^ (((task_id + 1) * GAMMA) & MASK64)
    s = ref_xoshiro_init(seed)
    for _ in range(draws + 1):
        s, result = ref_xoshiro_next(s)
    return np.float32(result >> 40) * _U24_SCALE


def ref_sample(probs: np.ndarray, top_p: float, u01: np.float32) -> int:
    """``TopPSampler::sample`` in np.float32 arithmetic, token only."""
    probs = probs.astype(np.float32)
    if top_p >= 0.999_999:
        total = np.float32(0.0)
        for p in probs:
            total = np.float32(total + p)
        u = np.float32(u01 * total)
        for i, p in enumerate(probs):
            u = np.float32(u - p)
            if u <= 0.0:
                return i
        return len(probs) - 1
    order = sorted(range(len(probs)), key=lambda i: (-probs[i], i))
    total = np.float32(0.0)
    for p in probs:
        total = np.float32(total + p)
    budget = np.float32(np.float32(top_p) * total)
    mass = np.float32(0.0)
    cut = len(order)
    for rank, i in enumerate(order):
        mass = np.float32(mass + probs[i])
        if mass >= budget:
            cut = rank + 1
            break
    kept = order[:cut]
    kept_mass = np.float32(0.0)
    for i in kept:
        kept_mass = np.float32(kept_mass + probs[i])
    u = np.float32(u01 * kept_mass)
    for i in kept:
        u = np.float32(u - probs[i])
        if u <= 0.0:
            return i
    return kept[-1]
