"""Fused log-softmax-gather + entropy as a Pallas kernel.

In the verification pass the model produces ``[B*G, V]`` logits and the
coordinator needs exactly two scalars per row: the log-prob of the realized
draft token and the entropy of the distribution. On real hardware the
naive formulation (materialize log-softmax, gather, reduce) is
memory-bound on the ``[N, V]`` intermediate; this kernel consumes each
``(block_n, V)`` tile in one VMEM pass — max, LSE, gather and entropy
computed before the tile is evicted.

Lowered with ``interpret=True`` for the CPU PJRT backend; oracle in
:mod:`ref` (``ref_logprob``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _logprob_kernel(logits_ref, tgt_ref, logp_ref, ent_ref, *, v):
    """One block_n-rows tile: logits [block_n, V], targets i32[block_n]."""
    x = logits_ref[...]
    tgt = tgt_ref[...]

    m = x.max(axis=1, keepdims=True)
    shifted = x - m
    expx = jnp.exp(shifted)
    denom = expx.sum(axis=1, keepdims=True)
    lse = jnp.log(denom) + m
    logp_all = x - lse
    p = expx / denom

    ent_ref[...] = -(p * logp_all).sum(axis=1)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) == tgt[:, None]
    ).astype(x.dtype)
    logp_ref[...] = (logp_all * onehot).sum(axis=1)


def logprob(logits, targets, *, block_n=None, interpret=True):
    """Shapes as :func:`ref.ref_logprob`: logits f32[N,V], targets i32[N].

    Returns ``(logp f32[N], entropy f32[N])``. N must divide by block_n.
    """
    n, v = logits.shape
    if block_n is None:
        from .attention import _pick_block

        block_n = _pick_block(n, 64)
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    lp, ent = pl.pallas_call(
        functools.partial(_logprob_kernel, v=v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, v), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(logits, targets)
    return lp, ent
